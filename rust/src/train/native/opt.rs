//! Adam (Kingma & Ba, 2015) over a flat parameter vector.
//!
//! The paper's training experiments (§4.2/§4.3) all use Adam; this is the
//! in-crate counterpart of the optimizer baked into the AOT `*_train_step`
//! artifacts, operating on the flattened `[cell θ | head θ]` layout of
//! [`super::model::Model`] (see the module docs of [`super`] for the exact
//! layout contract).

use crate::util::scalar::Scalar;

/// Adam hyper-parameters (defaults are the paper's / framework defaults).
#[derive(Debug, Clone)]
pub struct AdamConfig {
    pub lr: f64,
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
    /// Optional global-norm gradient clip applied before the moment update
    /// (0 ⇒ disabled). Long-sequence BPTT/DEER gradients can spike early in
    /// training; the clip keeps Seq and DEER arms comparable.
    pub grad_clip: f64,
}

impl Default for AdamConfig {
    fn default() -> Self {
        AdamConfig {
            lr: 1e-3,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            grad_clip: 0.0,
        }
    }
}

/// Adam state: first/second moment vectors plus the step counter.
#[derive(Debug, Clone)]
pub struct Adam<S> {
    pub cfg: AdamConfig,
    m: Vec<S>,
    v: Vec<S>,
    t: u64,
}

impl<S: Scalar> Adam<S> {
    pub fn new(num_params: usize, cfg: AdamConfig) -> Adam<S> {
        Adam {
            cfg,
            m: vec![S::zero(); num_params],
            v: vec![S::zero(); num_params],
            t: 0,
        }
    }

    /// Optimizer steps taken so far.
    pub fn steps(&self) -> u64 {
        self.t
    }

    /// One Adam update: `params -= lr · m̂ / (√v̂ + eps)` with bias-corrected
    /// moments. `grad` is consumed read-only (the clip rescale is folded
    /// into the moment update rather than mutating the caller's buffer).
    pub fn step(&mut self, params: &mut [S], grad: &[S]) {
        assert_eq!(params.len(), self.m.len(), "param/state length");
        assert_eq!(grad.len(), self.m.len(), "grad/state length");
        self.t += 1;
        let scale = if self.cfg.grad_clip > 0.0 {
            let norm = grad
                .iter()
                .map(|g| g.to_f64c() * g.to_f64c())
                .sum::<f64>()
                .sqrt();
            if norm > self.cfg.grad_clip {
                self.cfg.grad_clip / norm
            } else {
                1.0
            }
        } else {
            1.0
        };
        let b1 = S::from_f64c(self.cfg.beta1);
        let b2 = S::from_f64c(self.cfg.beta2);
        let one = S::one();
        let scale = S::from_f64c(scale);
        let c1 = S::from_f64c(1.0 - self.cfg.beta1.powi(self.t as i32));
        let c2 = S::from_f64c(1.0 - self.cfg.beta2.powi(self.t as i32));
        let lr = S::from_f64c(self.cfg.lr);
        let eps = S::from_f64c(self.cfg.eps);
        for i in 0..params.len() {
            let g = grad[i] * scale;
            self.m[i] = b1 * self.m[i] + (one - b1) * g;
            self.v[i] = b2 * self.v[i] + (one - b2) * g * g;
            let mhat = self.m[i] / c1;
            let vhat = self.v[i] / c2;
            params[i] -= lr * mhat / (vhat.sqrt() + eps);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Adam on a convex quadratic `Σ (p_i − c_i)²` reaches the minimum.
    #[test]
    fn converges_on_quadratic() {
        let target = [1.5f64, -0.5, 3.0];
        let mut p = vec![0.0f64; 3];
        let mut adam: Adam<f64> = Adam::new(3, AdamConfig { lr: 0.05, ..Default::default() });
        for _ in 0..2000 {
            let grad: Vec<f64> = p.iter().zip(target.iter()).map(|(p, c)| 2.0 * (p - c)).collect();
            adam.step(&mut p, &grad);
        }
        for (pi, ci) in p.iter().zip(target.iter()) {
            assert!((pi - ci).abs() < 1e-3, "{pi} vs {ci}");
        }
        assert_eq!(adam.steps(), 2000);
    }

    /// First step moves each coordinate by ≈ lr·sign(g) (bias correction).
    #[test]
    fn first_step_is_sign_scaled() {
        let mut p = vec![0.0f64; 2];
        let mut adam: Adam<f64> = Adam::new(2, AdamConfig { lr: 0.1, ..Default::default() });
        adam.step(&mut p, &[3.0, -0.7]);
        assert!((p[0] + 0.1).abs() < 1e-6, "{}", p[0]);
        assert!((p[1] - 0.1).abs() < 1e-6, "{}", p[1]);
    }

    /// Global-norm clipping rescales large gradients before the update.
    #[test]
    fn grad_clip_bounds_update() {
        let mut a = vec![0.0f64; 2];
        let mut b = vec![0.0f64; 2];
        let mut adam_a: Adam<f64> =
            Adam::new(2, AdamConfig { lr: 0.1, grad_clip: 1.0, ..Default::default() });
        let mut adam_b: Adam<f64> =
            Adam::new(2, AdamConfig { lr: 0.1, grad_clip: 1.0, ..Default::default() });
        adam_a.step(&mut a, &[30.0, 40.0]); // norm 50 → scaled by 1/50
        adam_b.step(&mut b, &[0.6, 0.8]); // norm 1 → untouched
        // Adam is scale-invariant per coordinate at step 1, so both updates
        // match: the clip must not change the direction.
        for (x, y) in a.iter().zip(b.iter()) {
            assert!((x - y).abs() < 1e-9, "{x} vs {y}");
        }
    }
}
