//! Adam (Kingma & Ba, 2015) over a flat parameter vector, with optional
//! learning-rate schedules.
//!
//! The paper's training experiments (§4.2/§4.3) all use Adam; this is the
//! in-crate counterpart of the optimizer baked into the AOT `*_train_step`
//! artifacts, operating on the flattened `[layer θ… | head θ]` layout of
//! [`super::model::Model`] (see the module docs of [`super`] for the exact
//! layout contract).
//!
//! [`LrSchedule`] scales the base learning rate per optimizer step
//! (constant | cosine | step-decay, each with an optional linear warmup).
//! The default [`LrSchedule::Constant`] multiplies by exactly `1.0`, so
//! runs without a schedule are **bitwise identical** to the pre-schedule
//! optimizer.

use crate::util::scalar::Scalar;

/// Per-step learning-rate scaling.
///
/// `factor(t)` maps the (1-based) optimizer step to a multiplier of the
/// base `lr`. All variants support a linear warmup ramp over the first
/// `warmup` steps (`warmup = 0` disables it).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum LrSchedule {
    /// `lr_t = lr` for every step (the default; factor is exactly 1.0).
    Constant,
    /// Linear warmup to `lr`, then cosine decay to 0 at step `total`
    /// (steps beyond `total` stay at 0-factor).
    Cosine { total: usize, warmup: usize },
    /// Linear warmup to `lr`, then multiply by `gamma` every `every`
    /// post-warmup steps (classic step decay).
    Step { every: usize, gamma: f64, warmup: usize },
}

impl LrSchedule {
    /// Multiplier of the base learning rate at (1-based) step `t`.
    pub fn factor(&self, t: u64) -> f64 {
        match *self {
            LrSchedule::Constant => 1.0,
            LrSchedule::Cosine { total, warmup } => {
                if warmup > 0 && t <= warmup as u64 {
                    return t as f64 / warmup as f64;
                }
                let total = (total.max(warmup + 1)) as f64;
                let w = warmup as f64;
                let prog = ((t as f64 - w) / (total - w)).clamp(0.0, 1.0);
                0.5 * (1.0 + (std::f64::consts::PI * prog).cos())
            }
            LrSchedule::Step { every, gamma, warmup } => {
                if warmup > 0 && t <= warmup as u64 {
                    return t as f64 / warmup as f64;
                }
                let drops = (t.saturating_sub(warmup as u64)) / every.max(1) as u64;
                gamma.powi(drops.min(i32::MAX as u64) as i32)
            }
        }
    }

    /// Parse a CLI spec:
    /// `constant` | `cosine:<total>[:<warmup>]` | `step:<every>:<gamma>[:<warmup>]`.
    pub fn parse(spec: &str) -> Result<LrSchedule, String> {
        let parts: Vec<&str> = spec.split(':').collect();
        let usize_at = |i: usize, what: &str| -> Result<usize, String> {
            parts
                .get(i)
                .ok_or_else(|| format!("lr-schedule {spec:?}: missing {what}"))?
                .parse::<usize>()
                .map_err(|e| format!("lr-schedule {spec:?}: bad {what}: {e}"))
        };
        match parts[0] {
            "constant" | "const" => Ok(LrSchedule::Constant),
            "cosine" => {
                let total = usize_at(1, "total steps")?;
                let warmup = if parts.len() > 2 { usize_at(2, "warmup")? } else { 0 };
                if total == 0 {
                    return Err(format!(
                        "lr-schedule {spec:?}: total must be ≥ 1 (a 0-step horizon freezes training)"
                    ));
                }
                if warmup >= total {
                    return Err(format!(
                        "lr-schedule {spec:?}: warmup ({warmup}) must be below total ({total})"
                    ));
                }
                Ok(LrSchedule::Cosine { total, warmup })
            }
            "step" => {
                let every = usize_at(1, "decay interval")?;
                if every == 0 {
                    return Err(format!("lr-schedule {spec:?}: decay interval must be ≥ 1"));
                }
                Ok(LrSchedule::Step {
                    every,
                    gamma: parts
                        .get(2)
                        .ok_or_else(|| format!("lr-schedule {spec:?}: missing gamma"))?
                        .parse::<f64>()
                        .map_err(|e| format!("lr-schedule {spec:?}: bad gamma: {e}"))?,
                    warmup: if parts.len() > 3 { usize_at(3, "warmup")? } else { 0 },
                })
            }
            other => Err(format!(
                "unknown lr-schedule {other:?} (constant | cosine:<total>[:<warmup>] | step:<every>:<gamma>[:<warmup>])"
            )),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            LrSchedule::Constant => "constant",
            LrSchedule::Cosine { .. } => "cosine",
            LrSchedule::Step { .. } => "step",
        }
    }

    /// Canonical spec string — round-trips through [`LrSchedule::parse`]
    /// exactly (f64 `Display` is shortest-round-trip), so checkpoints can
    /// persist the schedule and resumed runs can validate/adopt it.
    pub fn spec(&self) -> String {
        match *self {
            LrSchedule::Constant => "constant".to_string(),
            LrSchedule::Cosine { total, warmup } => format!("cosine:{total}:{warmup}"),
            LrSchedule::Step { every, gamma, warmup } => format!("step:{every}:{gamma}:{warmup}"),
        }
    }
}

/// Adam hyper-parameters (defaults are the paper's / framework defaults).
#[derive(Debug, Clone)]
pub struct AdamConfig {
    pub lr: f64,
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
    /// Optional global-norm gradient clip applied before the moment update
    /// (0 ⇒ disabled). Long-sequence BPTT/DEER gradients can spike early in
    /// training; the clip keeps Seq and DEER arms comparable.
    pub grad_clip: f64,
    /// Learning-rate schedule; [`LrSchedule::Constant`] (the default) is
    /// bitwise identical to the unscheduled optimizer.
    pub schedule: LrSchedule,
}

impl Default for AdamConfig {
    fn default() -> Self {
        AdamConfig {
            lr: 1e-3,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            grad_clip: 0.0,
            schedule: LrSchedule::Constant,
        }
    }
}

/// Adam state: first/second moment vectors plus the step counter.
#[derive(Debug, Clone)]
pub struct Adam<S> {
    pub cfg: AdamConfig,
    m: Vec<S>,
    v: Vec<S>,
    t: u64,
}

impl<S: Scalar> Adam<S> {
    pub fn new(num_params: usize, cfg: AdamConfig) -> Adam<S> {
        Adam {
            cfg,
            m: vec![S::zero(); num_params],
            v: vec![S::zero(); num_params],
            t: 0,
        }
    }

    /// Optimizer steps taken so far.
    pub fn steps(&self) -> u64 {
        self.t
    }

    /// First/second moment vectors (checkpointing).
    pub fn moments(&self) -> (&[S], &[S]) {
        (&self.m, &self.v)
    }

    /// Restore optimizer state from a checkpoint (moments + step counter).
    /// Lengths must match the parameter count this optimizer was built for.
    pub fn restore(&mut self, m: &[S], v: &[S], t: u64) {
        assert_eq!(m.len(), self.m.len(), "adam m length");
        assert_eq!(v.len(), self.v.len(), "adam v length");
        self.m.copy_from_slice(m);
        self.v.copy_from_slice(v);
        self.t = t;
    }

    /// One Adam update: `params -= lr · m̂ / (√v̂ + eps)` with bias-corrected
    /// moments. `grad` is consumed read-only (the clip rescale is folded
    /// into the moment update rather than mutating the caller's buffer).
    pub fn step(&mut self, params: &mut [S], grad: &[S]) {
        assert_eq!(params.len(), self.m.len(), "param/state length");
        assert_eq!(grad.len(), self.m.len(), "grad/state length");
        self.t += 1;
        let scale = if self.cfg.grad_clip > 0.0 {
            let norm = grad
                .iter()
                .map(|g| g.to_f64c() * g.to_f64c())
                .sum::<f64>()
                .sqrt();
            if norm > self.cfg.grad_clip {
                self.cfg.grad_clip / norm
            } else {
                1.0
            }
        } else {
            1.0
        };
        let b1 = S::from_f64c(self.cfg.beta1);
        let b2 = S::from_f64c(self.cfg.beta2);
        let one = S::one();
        let scale = S::from_f64c(scale);
        let c1 = S::from_f64c(1.0 - self.cfg.beta1.powi(self.t as i32));
        let c2 = S::from_f64c(1.0 - self.cfg.beta2.powi(self.t as i32));
        // schedule factor at this (1-based) step; Constant yields exactly
        // `lr * 1.0 == lr`, so unscheduled runs are bitwise unchanged
        let lr = S::from_f64c(self.cfg.lr * self.cfg.schedule.factor(self.t));
        let eps = S::from_f64c(self.cfg.eps);
        for i in 0..params.len() {
            let g = grad[i] * scale;
            self.m[i] = b1 * self.m[i] + (one - b1) * g;
            self.v[i] = b2 * self.v[i] + (one - b2) * g * g;
            let mhat = self.m[i] / c1;
            let vhat = self.v[i] / c2;
            params[i] -= lr * mhat / (vhat.sqrt() + eps);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Adam on a convex quadratic `Σ (p_i − c_i)²` reaches the minimum.
    #[test]
    fn converges_on_quadratic() {
        let target = [1.5f64, -0.5, 3.0];
        let mut p = vec![0.0f64; 3];
        let mut adam: Adam<f64> = Adam::new(3, AdamConfig { lr: 0.05, ..Default::default() });
        for _ in 0..2000 {
            let grad: Vec<f64> = p.iter().zip(target.iter()).map(|(p, c)| 2.0 * (p - c)).collect();
            adam.step(&mut p, &grad);
        }
        for (pi, ci) in p.iter().zip(target.iter()) {
            assert!((pi - ci).abs() < 1e-3, "{pi} vs {ci}");
        }
        assert_eq!(adam.steps(), 2000);
    }

    /// First step moves each coordinate by ≈ lr·sign(g) (bias correction).
    #[test]
    fn first_step_is_sign_scaled() {
        let mut p = vec![0.0f64; 2];
        let mut adam: Adam<f64> = Adam::new(2, AdamConfig { lr: 0.1, ..Default::default() });
        adam.step(&mut p, &[3.0, -0.7]);
        assert!((p[0] + 0.1).abs() < 1e-6, "{}", p[0]);
        assert!((p[1] - 0.1).abs() < 1e-6, "{}", p[1]);
    }

    /// Constant-schedule runs are bitwise identical to the base optimizer
    /// (factor is exactly 1.0 at every step).
    #[test]
    fn constant_schedule_is_bitwise_identity() {
        let mut a = vec![0.1f64, -0.2, 0.3];
        let mut b = a.clone();
        let mut adam_a: Adam<f64> = Adam::new(3, AdamConfig { lr: 0.07, ..Default::default() });
        let mut adam_b: Adam<f64> = Adam::new(
            3,
            AdamConfig { lr: 0.07, schedule: LrSchedule::Constant, ..Default::default() },
        );
        for s in 0..25 {
            let grad: Vec<f64> = a.iter().map(|p| 2.0 * p + s as f64 * 0.01).collect();
            adam_a.step(&mut a, &grad);
            adam_b.step(&mut b, &grad);
        }
        assert_eq!(a, b, "constant schedule changed the update bitwise");
    }

    /// Cosine: warmup ramps linearly, the post-warmup factor decays
    /// monotonically from 1 to 0 at `total`.
    #[test]
    fn cosine_schedule_shape() {
        let s = LrSchedule::Cosine { total: 100, warmup: 10 };
        assert!((s.factor(5) - 0.5).abs() < 1e-12, "warmup midpoint");
        assert!((s.factor(10) - 1.0).abs() < 1e-12, "end of warmup");
        let mut prev = 1.0 + 1e-12;
        for t in 11..=100 {
            let f = s.factor(t);
            assert!(f <= prev, "cosine not monotone at t={t}");
            prev = f;
        }
        assert!(s.factor(100) < 1e-12, "factor at total must reach 0");
        assert!(s.factor(500) < 1e-12, "factor beyond total stays 0");
        // no warmup: starts near 1
        let s0 = LrSchedule::Cosine { total: 50, warmup: 0 };
        assert!(s0.factor(1) > 0.99);
    }

    /// Step decay: ×gamma every `every` post-warmup steps.
    #[test]
    fn step_schedule_drops() {
        let s = LrSchedule::Step { every: 10, gamma: 0.5, warmup: 0 };
        assert!((s.factor(9) - 1.0).abs() < 1e-12);
        assert!((s.factor(10) - 0.5).abs() < 1e-12);
        assert!((s.factor(19) - 0.5).abs() < 1e-12);
        assert!((s.factor(20) - 0.25).abs() < 1e-12);
        let w = LrSchedule::Step { every: 10, gamma: 0.1, warmup: 4 };
        assert!((w.factor(2) - 0.5).abs() < 1e-12, "warmup ramp");
        assert!((w.factor(14) - 0.1).abs() < 1e-12, "first drop at warmup+every");
    }

    #[test]
    fn schedule_parse() {
        assert_eq!(LrSchedule::parse("constant").unwrap(), LrSchedule::Constant);
        assert_eq!(
            LrSchedule::parse("cosine:200").unwrap(),
            LrSchedule::Cosine { total: 200, warmup: 0 }
        );
        assert_eq!(
            LrSchedule::parse("cosine:200:20").unwrap(),
            LrSchedule::Cosine { total: 200, warmup: 20 }
        );
        assert_eq!(
            LrSchedule::parse("step:50:0.5:10").unwrap(),
            LrSchedule::Step { every: 50, gamma: 0.5, warmup: 10 }
        );
        assert!(LrSchedule::parse("cosine").is_err());
        assert!(LrSchedule::parse("step:10").is_err());
        assert!(LrSchedule::parse("poly:2").is_err());
        // degenerate horizons are rejected, not silently rewritten
        assert!(LrSchedule::parse("cosine:0").is_err(), "0-step horizon freezes training");
        assert!(LrSchedule::parse("cosine:10:10").is_err(), "warmup must end before total");
        assert!(LrSchedule::parse("step:0:0.5").is_err(), "0-step decay interval");
    }

    /// spec() round-trips through parse() exactly — the checkpoint
    /// persistence contract.
    #[test]
    fn spec_round_trips() {
        for s in [
            LrSchedule::Constant,
            LrSchedule::Cosine { total: 200, warmup: 20 },
            LrSchedule::Step { every: 50, gamma: 0.5, warmup: 10 },
            LrSchedule::Step { every: 7, gamma: 0.333_333_333_333, warmup: 0 },
        ] {
            assert_eq!(LrSchedule::parse(&s.spec()).unwrap(), s, "{}", s.spec());
        }
    }

    /// Scheduled Adam applies the factor to the step size: with lr γ-decayed
    /// to ~0 the parameters stop moving.
    #[test]
    fn scheduled_adam_freezes_after_decay() {
        let mut p = vec![0.0f64; 2];
        let cfg = AdamConfig {
            lr: 0.1,
            schedule: LrSchedule::Step { every: 5, gamma: 0.0, warmup: 0 },
            ..Default::default()
        };
        let mut adam: Adam<f64> = Adam::new(2, cfg);
        for _ in 0..4 {
            adam.step(&mut p, &[1.0, -1.0]);
        }
        let frozen = p.clone();
        for _ in 0..10 {
            adam.step(&mut p, &[1.0, -1.0]);
        }
        assert_eq!(p, frozen, "zero-factor steps must not move parameters");
    }

    /// Moments + step counter round-trip through restore (checkpointing).
    #[test]
    fn restore_resumes_identically() {
        let mut p1 = vec![0.0f64; 3];
        let mut adam1: Adam<f64> = Adam::new(3, AdamConfig { lr: 0.05, ..Default::default() });
        for s in 0..7 {
            let g: Vec<f64> = p1.iter().map(|v| v - s as f64).collect();
            adam1.step(&mut p1, &g);
        }
        // snapshot, continue the original
        let (m, v) = adam1.moments();
        let (m, v) = (m.to_vec(), v.to_vec());
        let t = adam1.steps();
        let snap_p = p1.clone();
        let mut adam2: Adam<f64> = Adam::new(3, AdamConfig { lr: 0.05, ..Default::default() });
        adam2.restore(&m, &v, t);
        let mut p2 = snap_p.clone();
        for s in 7..12 {
            let g1: Vec<f64> = p1.iter().map(|v| v - s as f64).collect();
            adam1.step(&mut p1, &g1);
            let g2: Vec<f64> = p2.iter().map(|v| v - s as f64).collect();
            adam2.step(&mut p2, &g2);
        }
        assert_eq!(p1, p2, "restored optimizer must continue bitwise identically");
    }

    /// Global-norm clipping rescales large gradients before the update.
    #[test]
    fn grad_clip_bounds_update() {
        let mut a = vec![0.0f64; 2];
        let mut b = vec![0.0f64; 2];
        let mut adam_a: Adam<f64> =
            Adam::new(2, AdamConfig { lr: 0.1, grad_clip: 1.0, ..Default::default() });
        let mut adam_b: Adam<f64> =
            Adam::new(2, AdamConfig { lr: 0.1, grad_clip: 1.0, ..Default::default() });
        adam_a.step(&mut a, &[30.0, 40.0]); // norm 50 → scaled by 1/50
        adam_b.step(&mut b, &[0.6, 0.8]); // norm 1 → untouched
        // Adam is scale-invariant per coordinate at step 1, so both updates
        // match: the clip must not change the direction.
        for (x, y) in a.iter().zip(b.iter()) {
            assert!((x - y).abs() < 1e-9, "{x} vs {y}");
        }
    }
}
