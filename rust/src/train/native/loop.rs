//! Minibatch training loop: data → fused batched solve → gradients → Adam.
//!
//! Every optimizer step draws a shuffled minibatch from the
//! [`crate::data::Dataset`] splits and dispatches the forward evaluation
//! through one of three interchangeable engines
//! ([`TrainConfig::mode`]):
//!
//! * [`ForwardMode::Seq`] — the sequential baseline: step-by-step forward
//!   (via the fused [`crate::deer::seq::seq_rnn_batch`]) + BPTT. This is
//!   the "commonly-used sequential method" of §4.1, single-threaded by
//!   construction.
//! * [`ForwardMode::Deer`] — the minibatch is submitted to the
//!   coordinator's [`BatchExecutor`] and runs as **ONE** fused `[B, T, n]`
//!   Newton solve (per-sequence convergence masking, sequential fallback
//!   for stragglers), warm-started across epochs from the executor's
//!   trajectory cache (App. B.2: the previous visit's trajectory is the
//!   initial guess, so mid-training solves need only a few sweeps). The
//!   backward pass is the exact eq.-7 dual scan — identical gradients to
//!   BPTT up to the forward tolerance.
//! * [`ForwardMode::QuasiDeer`] — same dispatch with
//!   [`JacobianMode::DiagonalApprox`] Jacobians and the
//!   [`TrainConfig::step_clamp`] trust radius, trading exact dense algebra
//!   for O(n) scans (the gradient drops off-diagonal λ-propagation on
//!   dense cells — see `crate::deer::grad`).
//! * [`ForwardMode::Hybrid`] — [`JacobianMode::Hybrid`] forward (dense
//!   Newton until the residual crosses [`TrainConfig::hybrid_threshold`],
//!   then the O(n) diagonal endgame) with the exact dense backward —
//!   cheaper forward sweeps, Deer-quality gradients.
//! * [`ForwardMode::Elk`] / [`ForwardMode::QuasiElk`] — the damped
//!   (Levenberg–Marquardt) solver: Deer / QuasiDeer dispatch with
//!   [`TrainConfig::damping_lambda0`] enabling per-sequence adaptive
//!   damping (trial steps accept/reject, λ grows on residual increase),
//!   so mid-training ill-conditioned cells converge where the undamped
//!   iteration diverges. The backward pass reuses each sequence's last
//!   accepted λ in the damped dual scan
//!   ([`crate::deer::grad::deer_rnn_backward_batch_damped_io`]).
//!   QuasiElk needs no [`TrainConfig::step_clamp`]: adaptive damping
//!   subsumes the fixed trust radius.
//!
//! Seq vs Deer is therefore a pure A/B switch: data order, loss algebra,
//! optimizer state and seeds are shared; only the trajectory/gradient
//! engine changes. The loop emits [`CurvePoint`]s (loss / accuracy /
//! wall-clock) after every step — the Fig. 4-style training curves.
//!
//! # Stacked layers
//!
//! With an `L`-layer [`Model`] the forward pass runs **one fused batched
//! solve per layer per minibatch** (layer `l`'s `[B, T, n]` trajectory is
//! layer `l + 1`'s input sequence — the ParaRNN layerwise formulation),
//! each layer warm-started from its OWN trajectory cache (per-layer cache,
//! keyed by dataset row). The backward pass walks the stack in reverse:
//! layer `l`'s input cotangents (`dxs` of
//! [`crate::deer::grad::deer_rnn_backward_batch_io`], or the BPTT
//! input-VJP in Seq mode) become layer `l − 1`'s output cotangents `gs`,
//! and each layer's `dθ` lands in its own slice of the flat gradient
//! ([`Model::layer_param_range`]). [`TrainStats::solves_per_layer`] pins
//! the one-solve-per-layer dispatch invariant.

use std::time::{Duration, Instant};

use crate::cells::{CellGrad, JacobianStructure};
use crate::coordinator::exec::BatchExecutor;
use crate::coordinator::policy::EvalPath;
use crate::coordinator::warmstart::WarmStartCache;
use crate::data::{Dataset, Split};
use crate::deer::grad::deer_rnn_backward_batch_damped_io;
use crate::deer::newton::{effective_structure, JacobianMode};
use crate::deer::ode::{deer_ode_backward_batch, FieldSystem};
use crate::deer::sharded::deer_rnn_backward_sharded;
use crate::deer::seq::{seq_rnn, seq_rnn_backward_io, seq_rnn_batch};
use crate::train::CurvePoint;
use crate::util::err::Result;
use crate::util::rng::Rng;
use crate::bail;

use super::model::Model;
use super::opt::{Adam, AdamConfig, LrSchedule};

/// Which engine evaluates (and differentiates) the recurrence.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ForwardMode {
    /// Sequential forward + BPTT (the paper's baseline).
    Seq,
    /// Fused batched DEER through the coordinator (exact Newton).
    Deer,
    /// Fused batched quasi-DEER (DiagonalApprox + trust radius).
    QuasiDeer,
    /// Fused batched hybrid-Newton forward ([`JacobianMode::Hybrid`]:
    /// dense until the residual crosses
    /// [`TrainConfig::hybrid_threshold`], diagonal endgame) with the exact
    /// dense eq.-7 backward — forward Jacobians are NOT reused (the
    /// endgame leaves them in the diagonal layout), so gradients match the
    /// Deer arm to tolerance.
    Hybrid,
    /// Fused batched ELK: exact dense Newton with adaptive per-sequence
    /// LM damping (accept/reject trial steps) and the matching damped
    /// backward dual — the divergence-robust arm.
    Elk,
    /// Fused batched quasi-ELK: DiagonalApprox Jacobians under the same
    /// adaptive damping; replaces QuasiDeer's fixed `step_clamp` trust
    /// radius with per-sequence λ adaptation.
    QuasiElk,
}

impl ForwardMode {
    /// Parse a CLI token (`seq` | `deer` | `quasi` | `hybrid`).
    pub fn parse(s: &str) -> Result<ForwardMode, String> {
        match s {
            "seq" => Ok(ForwardMode::Seq),
            "deer" => Ok(ForwardMode::Deer),
            "quasi" | "quasideer" | "quasi-deer" => Ok(ForwardMode::QuasiDeer),
            "hybrid" => Ok(ForwardMode::Hybrid),
            "elk" => Ok(ForwardMode::Elk),
            "quasi-elk" | "quasielk" => Ok(ForwardMode::QuasiElk),
            other => Err(format!(
                "unknown forward mode {other:?} (seq|deer|quasi|hybrid|elk|quasi-elk)"
            )),
        }
    }

    /// Parse a comma-separated per-layer mode list (`deer,seq` → layer 0
    /// fused DEER, layer 1 sequential). A single token means "every layer".
    pub fn parse_modes(s: &str) -> Result<Vec<ForwardMode>, String> {
        s.split(',').map(|tok| ForwardMode::parse(tok.trim())).collect()
    }

    pub fn label(&self) -> &'static str {
        match self {
            ForwardMode::Seq => "seq",
            ForwardMode::Deer => "deer",
            ForwardMode::QuasiDeer => "quasi",
            ForwardMode::Hybrid => "hybrid",
            ForwardMode::Elk => "elk",
            ForwardMode::QuasiElk => "quasi-elk",
        }
    }

    /// The solver-side Jacobian mode this training arm dispatches with.
    fn jacobian_mode(&self) -> JacobianMode {
        match self {
            ForwardMode::Seq | ForwardMode::Deer | ForwardMode::Elk => JacobianMode::Full,
            ForwardMode::QuasiDeer | ForwardMode::QuasiElk => JacobianMode::DiagonalApprox,
            ForwardMode::Hybrid => JacobianMode::Hybrid,
        }
    }

    /// Whether this arm runs the damped (ELK) solver by default.
    pub fn is_elk(&self) -> bool {
        matches!(self, ForwardMode::Elk | ForwardMode::QuasiElk)
    }
}

/// Regression targets rider for a [`Dataset`] (whose own labels are class
/// ids): `values` is `[rows, k]` row-major.
#[derive(Debug, Clone)]
pub struct Targets {
    pub k: usize,
    pub values: Vec<f32>,
}

/// A training task: the dataset plus (for regression) per-row targets.
/// `targets: None` ⇒ classification on `ds.labels`.
#[derive(Debug, Clone)]
pub struct TrainData {
    pub ds: Dataset,
    pub targets: Option<Targets>,
}

/// Loop configuration. `Default` is the §4.3-style classifier setting.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub mode: ForwardMode,
    /// Minibatch size B (one fused solve per minibatch).
    pub batch: usize,
    pub lr: f64,
    /// Global-norm gradient clip (0 = off) — applied identically in every
    /// mode so the A/B comparison stays fair.
    pub grad_clip: f64,
    /// Worker threads handed to the fused batched solves.
    pub threads: usize,
    /// Shuffling / init seed. Two loops with equal seeds and configs see
    /// identical data order.
    pub seed: u64,
    /// Forward tolerance override (None = paper default for the dtype).
    pub tol_override: Option<f64>,
    pub max_iter: usize,
    /// Trust radius forwarded to the solver (quasi-DEER safeguard).
    pub step_clamp: Option<f64>,
    /// Hybrid-mode endgame switch point, forwarded to
    /// [`crate::deer::DeerConfig::hybrid_threshold`] (only read by
    /// [`ForwardMode::Hybrid`]).
    pub hybrid_threshold: f64,
    /// Initial LM damping λ₀ for the ELK arms (None → 1.0 when the mode
    /// is [`ForwardMode::Elk`] / [`ForwardMode::QuasiElk`], undamped
    /// otherwise). Setting it on a non-ELK Deer arm also enables damping —
    /// the `--lambda0` CLI escape hatch.
    pub damping_lambda0: Option<f64>,
    /// Per-step divergence observability: print each sequence's iteration
    /// count, λ / residual traces and stop reason to stderr
    /// (`deer train --verbose`).
    pub verbose: bool,
    /// Reuse forward Jacobians in the backward pass (speed) instead of
    /// recomputing them along the converged trajectory (memory + a
    /// tolerance-level exactness gain) — the §3.1.1 trade-off.
    pub reuse_jacobians: bool,
    /// Learning-rate schedule ([`LrSchedule::Constant`] by default —
    /// bitwise identical to the unscheduled optimizer).
    pub lr_schedule: LrSchedule,
    /// Sequence shards S for windowed DEER (`--shards`): each fused solve
    /// runs T as S windows of W = ⌈T/S⌉ through the executor's sharded
    /// dispatch, and the backward pass chains the dual scan across window
    /// boundaries ([`crate::deer::sharded`]) — peak solver memory drops
    /// from O(B·T·jac) to O(B·W·jac) while exact stitching keeps
    /// trajectories AND gradients bitwise-identical to the unsharded path
    /// at `threads = 1`. `1` (default) = unsharded. Seq layers ignore it;
    /// the damped ELK arms reject it (the sharded dual is undamped-only).
    pub shards: usize,
    /// Per-layer engine override (`--mode deer,seq`): index = layer. None
    /// ⇒ every layer runs [`TrainConfig::mode`]. Length must equal the
    /// model's layer count.
    pub layer_modes: Option<Vec<ForwardMode>>,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            mode: ForwardMode::Deer,
            batch: 8,
            lr: 3e-3,
            grad_clip: 0.0,
            threads: 1,
            seed: 0,
            tol_override: None,
            max_iter: 100,
            step_clamp: None,
            hybrid_threshold: 1e-2,
            damping_lambda0: None,
            verbose: false,
            reuse_jacobians: true,
            lr_schedule: LrSchedule::Constant,
            shards: 1,
            layer_modes: None,
        }
    }
}

impl TrainConfig {
    /// The λ₀ actually handed to the convergence policy: the explicit
    /// override wins, else the ELK arms default to 1.0 and every other arm
    /// stays undamped.
    pub fn effective_lambda0(&self) -> Option<f64> {
        self.damping_lambda0
            .or_else(|| self.mode.is_elk().then_some(1.0))
    }

    /// The engine layer `l` dispatches through: its [`TrainConfig::layer_modes`]
    /// entry when the per-layer list is set, [`TrainConfig::mode`] otherwise.
    pub fn mode_for_layer(&self, l: usize) -> ForwardMode {
        self.layer_modes
            .as_ref()
            .and_then(|v| v.get(l).copied())
            .unwrap_or(self.mode)
    }

    /// Layer-aware [`TrainConfig::effective_lambda0`]: the explicit
    /// override still applies to every layer; otherwise only layers whose
    /// per-layer mode is an ELK arm get the damped default.
    pub fn lambda0_for_layer(&self, l: usize) -> Option<f64> {
        self.damping_lambda0
            .or_else(|| self.mode_for_layer(l).is_elk().then_some(1.0))
    }
}

/// Aggregate counters over a training run.
#[derive(Debug, Clone, Default)]
pub struct TrainStats {
    pub steps: usize,
    pub epochs: usize,
    /// Fused solves issued, summed over layers (Deer modes: exactly one
    /// per layer per minibatch unless the memory planner split a group).
    pub batched_solves: u64,
    pub sequences_solved: u64,
    /// Sequences that fell back to the sequential evaluator.
    pub fallbacks: u64,
    /// Sequences whose initial guess came from the warm-start cache.
    pub warm_started: u64,
    /// Total Newton sweeps summed over sequences.
    pub newton_iters: u64,
    pub fwd_secs: f64,
    pub bwd_secs: f64,
    /// Fused solves per layer (index = layer): the per-layer view of the
    /// ONE-solve-per-layer-per-minibatch dispatch invariant.
    pub solves_per_layer: Vec<u64>,
    /// Sequences whose solve froze on a non-finite residual/state.
    pub diverged_nonfinite: u64,
    /// Sequences that exhausted the ELK damping budget.
    pub diverged_lambda_exhausted: u64,
    /// Sequences that hit the iteration cap without converging.
    pub diverged_max_iters: u64,
    /// Sequences stopped by the divergence patience.
    pub diverged_error_growth: u64,
    /// Per-sequence Hybrid Full→Diagonal endgame switches.
    pub hybrid_switches: u64,
    /// Sharded (windowed) fused solves dispatched (`--shards` > 1).
    pub shard_solves: u64,
    /// Window-rows solved across all sharded dispatches.
    pub shard_windows: u64,
    /// Outer stitch iterations summed over sharded solves (exact
    /// stitching contributes 1 per solve).
    pub stitch_iters: u64,
}

/// Per-step outcome.
#[derive(Debug, Clone, Copy)]
pub struct StepStats {
    pub step: usize,
    pub loss: f64,
    pub acc: Option<f64>,
    pub fwd_secs: f64,
    pub bwd_secs: f64,
}

/// Result of differentiating one minibatch (exposed for tests: the Seq and
/// Deer engines must agree on this to forward-tolerance level).
#[derive(Debug, Clone)]
pub struct MinibatchGrad {
    /// Flat `[cell | head]` gradient.
    pub grad: Vec<f32>,
    pub loss: f64,
    pub acc: Option<f64>,
    pub fwd_secs: f64,
    pub bwd_secs: f64,
}

/// The native minibatch trainer.
pub struct TrainLoop<C: CellGrad<f32>> {
    pub model: Model<f32, C>,
    pub data: TrainData,
    pub cfg: TrainConfig,
    pub opt: Adam<f32>,
    pub curve: Vec<CurvePoint>,
    pub stats: TrainStats,
    /// Per-layer warm-start trajectory caches (index = layer), persistent
    /// across steps/epochs and swapped into each layer's per-step
    /// [`BatchExecutor`]. Separate caches keep layer trajectories from
    /// colliding on the shared row-id key space.
    caches: Vec<WarmStartCache>,
    params: Vec<f32>,
    order: Vec<usize>,
    rng: Rng,
    started: Instant,
}

impl<C: CellGrad<f32>> TrainLoop<C> {
    /// Validate the (model, data, config) triple and build the loop. All
    /// misconfigurations — empty/undersized train split, label range,
    /// target layout, channel mismatch — surface as clean [`Result`]
    /// errors instead of aborting the process.
    pub fn new(model: Model<f32, C>, data: TrainData, cfg: TrainConfig) -> Result<TrainLoop<C>> {
        if cfg.batch == 0 {
            bail!("batch must be ≥ 1");
        }
        let train_len = data.ds.split_len(Split::Train);
        if train_len < cfg.batch {
            bail!(
                "train split ({train_len} rows) smaller than batch ({}): lower --batch or add rows",
                cfg.batch
            );
        }
        if model.input_dim() != data.ds.channels {
            bail!(
                "model layer 0 expects {} input channels, dataset has {}",
                model.input_dim(),
                data.ds.channels
            );
        }
        match &data.targets {
            None => model.validate_labels(&data.ds.labels)?,
            Some(tg) => {
                if tg.values.len() != data.ds.rows * tg.k {
                    bail!(
                        "targets layout: {} values for {} rows × k = {}",
                        tg.values.len(),
                        data.ds.rows,
                        tg.k
                    );
                }
                if tg.k != model.k {
                    bail!("target dim {} vs {}-output head", tg.k, model.k);
                }
            }
        }
        if let Some(modes) = &cfg.layer_modes {
            if modes.len() != model.layers() {
                bail!(
                    "--mode lists {} per-layer entries for a {}-layer model",
                    modes.len(),
                    model.layers()
                );
            }
        }
        if cfg.shards == 0 {
            bail!("--shards must be ≥ 1");
        }
        if cfg.shards > 1 {
            let damped = cfg.damping_lambda0.is_some()
                || (0..model.layers()).any(|l| cfg.mode_for_layer(l).is_elk());
            if damped {
                bail!(
                    "--shards is incompatible with the damped ELK arms (the sharded \
                     window-chained backward is undamped-only): drop --lambda0 / use \
                     deer|quasi|hybrid|seq"
                );
            }
        }
        // Continuous-time (OdeCell) layers: the trainer integrates the layer
        // as one fused DEER-ODE solve over the [0, T·dt] grid. The dataset
        // row's FIRST frame is the initial condition y(0) (there is no
        // per-step input channel — the field is autonomous), so the
        // construction is single-layer with m = n by the cell's definition.
        if model.cells().iter().any(|c| c.ode_view().is_some()) {
            if model.layers() != 1 {
                bail!(
                    "continuous-time OdeCell models must be single-layer (got {} layers): \
                     the ODE grid has no inter-layer input sequence",
                    model.layers()
                );
            }
            if cfg.shards > 1 {
                bail!(
                    "--shards is incompatible with the continuous-time ODE path \
                     (the ODE dual scan runs unsharded)"
                );
            }
            for l in 0..model.layers() {
                let m = cfg.mode_for_layer(l);
                if !matches!(m, ForwardMode::Seq | ForwardMode::Deer) {
                    bail!(
                        "ODE layers run --mode seq|deer only (got {}): the quasi/hybrid/elk \
                         arms are discrete-Jacobian constructions with no continuous analogue \
                         wired up",
                        m.label()
                    );
                }
            }
        }
        let p = model.num_params();
        let mut params = vec![0.0f32; p];
        model.write_params(&mut params);
        // One cache per layer, each sized to hold every row's trajectory at
        // that layer's width with headroom, so warm starts survive whole
        // epochs.
        let caches = (0..model.layers())
            .map(|l| {
                let n_l = model.cell(l).state_dim();
                WarmStartCache::new(data.ds.rows * (data.ds.t * n_l * 4 + 128) * 2)
            })
            .collect();
        let opt = Adam::new(
            p,
            AdamConfig {
                lr: cfg.lr,
                grad_clip: cfg.grad_clip,
                schedule: cfg.lr_schedule,
                ..Default::default()
            },
        );
        let rng = Rng::new(cfg.seed ^ 0x7261_696e);
        let stats = TrainStats {
            solves_per_layer: vec![0; model.layers()],
            ..TrainStats::default()
        };
        Ok(TrainLoop {
            model,
            data,
            cfg,
            opt,
            curve: Vec::new(),
            stats,
            caches,
            params,
            order: Vec::new(),
            rng,
            started: Instant::now(),
        })
    }

    /// Flat `[cells… | head]` parameters (the optimizer's view).
    pub fn params(&self) -> &[f32] {
        &self.params
    }

    /// Warm-start cache hit rate so far, aggregated over layers.
    pub fn cache_hit_rate(&self) -> f64 {
        let mut hits = 0u64;
        let mut total = 0u64;
        for c in &self.caches {
            hits += c.hits;
            total += c.hits + c.misses;
        }
        if total == 0 {
            0.0
        } else {
            hits as f64 / total as f64
        }
    }

    /// Save the training state (flat parameters, Adam moments, step
    /// counter, LR-schedule spec) as a JSON checkpoint.
    pub fn save_checkpoint(&self, path: &std::path::Path) -> Result<()> {
        super::checkpoint::save(
            path,
            &self.params,
            &self.opt,
            self.model.layers(),
            &self.cfg.lr_schedule.spec(),
        )
    }

    /// Restore parameters + optimizer state from a checkpoint written by
    /// [`TrainLoop::save_checkpoint`]. The checkpoint must match this
    /// loop's parameter count and layer count. Params, Adam moments and
    /// the step counter resume bitwise; the data-stream state (shuffle
    /// RNG / in-epoch order / epoch counter) is not checkpointed, so the
    /// resumed run draws a fresh shuffle — see the [`super::checkpoint`]
    /// module docs.
    pub fn load_checkpoint(&mut self, path: &std::path::Path) -> Result<()> {
        let ck = super::checkpoint::load(path)?;
        if ck.params.len() != self.params.len() {
            bail!(
                "checkpoint has {} parameters, model has {}",
                ck.params.len(),
                self.params.len()
            );
        }
        if ck.layers != self.model.layers() {
            bail!(
                "checkpoint was saved from a {}-layer model, this model has {} layers",
                ck.layers,
                self.model.layers()
            );
        }
        // the restored step counter only keeps meaning the same LR factor
        // if the schedule matches; a silent fallback to a different one
        // would jump the learning rate discontinuously on resume
        if let Some(spec) = &ck.lr_schedule {
            let ours = self.cfg.lr_schedule.spec();
            if *spec != ours {
                bail!(
                    "checkpoint was saved with lr-schedule {spec}, this run uses {ours}: pass \
                     --lr-schedule {spec} to resume it (or re-save under the new schedule)"
                );
            }
        }
        self.params.copy_from_slice(&ck.params);
        self.model.load_params(&self.params);
        self.opt.restore(&ck.adam_m, &ck.adam_v, ck.step);
        // keep step numbering aligned with the optimizer (and hence the LR
        // schedule): resumed curves continue at ck.step + 1 instead of
        // renumbering from 1 while Adam applies factor(ck.step + i)
        self.stats.steps = ck.step as usize;
        Ok(())
    }

    /// Draw the next shuffled minibatch of absolute train-row ids,
    /// reshuffling (a new epoch) when the current pass is exhausted.
    fn next_batch(&mut self) -> Vec<usize> {
        let b = self.cfg.batch;
        if self.order.len() < b {
            // train rows are 0..train_len in the loader's 70/15/15 layout
            let train_len = self.data.ds.split_len(Split::Train);
            self.order = self.rng.permutation(train_len);
            self.stats.epochs += 1;
        }
        self.order.split_off(self.order.len() - b)
    }

    /// One layer's forward over the minibatch. `input` is the layer's
    /// `[B, T, m_l]` input sequence — the gathered dataset rows for layer
    /// 0, the layer-below trajectory otherwise. Deer modes dispatch the
    /// whole minibatch as ONE fused solve through a per-layer
    /// [`BatchExecutor`] (warm-started from this layer's cache); returns
    /// the `[B, T, n_l]` trajectory, the retained forward Jacobians, and
    /// the per-sequence accepted damping λ (all zeros outside the ELK
    /// arms — and zeroed for fallback rows, whose exact sequential
    /// trajectory wants the undamped dual).
    fn forward_layer(
        &mut self,
        l: usize,
        rows: &[usize],
        input: &[f32],
        b: usize,
    ) -> (Vec<f32>, Option<(Vec<f32>, JacobianStructure)>, Vec<f32>) {
        let _layer_span = crate::telemetry::span_with(
            "layer_solve",
            vec![
                ("layer", crate::telemetry::ArgValue::Num(l as f64)),
                ("rows", crate::telemetry::ArgValue::Num(b as f64)),
            ],
        );
        let t_len = self.data.ds.t;
        let cell = self.model.cell(l);
        let n = cell.state_dim();
        let m = cell.input_dim();
        // Continuous-time layers start from the trajectory's first frame
        // (the ODE initial condition), not a zero state: both engines
        // integrate y(0) = x_0 forward and otherwise ignore the inputs.
        let mut h0s = vec![0.0f32; b * n];
        if cell.ode_view().is_some() {
            for s in 0..b {
                h0s[s * n..(s + 1) * n]
                    .copy_from_slice(&input[s * t_len * m..s * t_len * m + n]);
            }
        }
        let mode = self.cfg.mode_for_layer(l);
        match mode {
            ForwardMode::Seq => (seq_rnn_batch(cell, &h0s, input, b), None, vec![0.0; b]),
            ForwardMode::Deer
            | ForwardMode::QuasiDeer
            | ForwardMode::Hybrid
            | ForwardMode::Elk
            | ForwardMode::QuasiElk => {
                let jacobian_mode = mode.jacobian_mode();
                let structure = effective_structure(cell, jacobian_mode);
                let jl = structure.jac_len(n);
                // Hybrid never reuses forward Jacobians: the endgame switch
                // leaves them in the diagonal layout while the backward pass
                // runs the exact dense dual scan. Sharded solves never
                // retain them either (they only exist per window).
                let reuse = self.cfg.reuse_jacobians
                    && mode != ForwardMode::Hybrid
                    && self.cfg.shards == 1;
                let mut ex = BatchExecutor::new(
                    cell,
                    t_len,
                    b,
                    Duration::from_secs(3600),
                    0, // replaced by the persistent per-layer cache below
                    1u64 << 40,
                    self.cfg.threads,
                );
                ex.layer = l;
                ex.plan_layers = self.model.layers();
                // heterogeneous stacks: peers are budgeted at the stack's
                // widest layer so the plan never understates retained slabs
                ex.plan_peer_width = self
                    .model
                    .cells()
                    .iter()
                    .map(|c| c.state_dim())
                    .max()
                    .unwrap_or(n);
                ex.policy.tol_override = self.cfg.tol_override;
                ex.policy.max_iter = self.cfg.max_iter;
                ex.policy.jacobian_mode = jacobian_mode;
                ex.policy.step_clamp = self.cfg.step_clamp;
                ex.policy.hybrid_threshold = self.cfg.hybrid_threshold;
                ex.policy.damping_lambda0 = self.cfg.lambda0_for_layer(l);
                ex.keep_jacobians = reuse;
                ex.shards = self.cfg.shards;
                std::mem::swap(&mut ex.cache, &mut self.caches[l]);

                let mut replies = Vec::with_capacity(b);
                for (s, &row) in rows.iter().enumerate() {
                    let r = ex.submit(
                        row as u64,
                        h0s[s * n..(s + 1) * n].to_vec(),
                        input[s * t_len * m..(s + 1) * t_len * m].to_vec(),
                    );
                    replies.extend(r);
                }
                replies.extend(ex.flush());
                std::mem::swap(&mut ex.cache, &mut self.caches[l]);
                self.stats.batched_solves += ex.stats.batched_solves;
                self.stats.sequences_solved += ex.stats.sequences_solved;
                self.stats.solves_per_layer[l] += ex.stats.batched_solves;
                self.stats.diverged_nonfinite += ex.stats.diverged_nonfinite;
                self.stats.diverged_lambda_exhausted += ex.stats.diverged_lambda_exhausted;
                self.stats.diverged_max_iters += ex.stats.diverged_max_iters;
                self.stats.diverged_error_growth += ex.stats.diverged_error_growth;
                self.stats.hybrid_switches += ex.stats.hybrid_switches;
                self.stats.shard_solves += ex.stats.shard_solves;
                self.stats.shard_windows += ex.stats.shard_windows;
                self.stats.stitch_iters += ex.stats.stitch_iters;
                assert_eq!(replies.len(), b, "one reply per minibatch sequence");

                // scatter replies back into submission order; rows may
                // contain duplicates (grad_minibatch is public), so each
                // reply claims the first still-unfilled matching slot
                let mut ys = vec![0.0f32; b * t_len * n];
                let mut jac = vec![0.0f32; if reuse { b * t_len * jl } else { 0 }];
                let mut lambdas = vec![0.0f32; b];
                let mut all_jac = reuse;
                let mut filled = vec![false; b];
                for reply in &replies {
                    let s = rows
                        .iter()
                        .enumerate()
                        .position(|(k, &r)| !filled[k] && r as u64 == reply.sample_id)
                        .expect("reply for unknown row");
                    filled[s] = true;
                    ys[s * t_len * n..(s + 1) * t_len * n].copy_from_slice(&reply.ys);
                    // a fallback row's trajectory is the EXACT sequential
                    // evaluation — its dual must run undamped
                    lambdas[s] = if reply.path == EvalPath::SequentialFallback {
                        0.0
                    } else {
                        reply.lambda
                    };
                    if self.cfg.verbose {
                        eprintln!(
                            "[train verbose] layer {l} row {} iters {} converged {} path {:?} \
                             lambda {:.3e} reason {} err_trace {:?} lambda_trace {:?}",
                            reply.sample_id,
                            reply.iterations,
                            reply.converged,
                            reply.path,
                            reply.lambda,
                            reply.divergence.map(|d| d.label()).unwrap_or("-"),
                            reply.err_trace,
                            reply.lambda_trace,
                        );
                    }
                    match &reply.jacobians {
                        Some(j) => {
                            assert_eq!(
                                reply.jac_structure, structure,
                                "executor returned a different Jacobian layout than planned"
                            );
                            jac[s * t_len * jl..(s + 1) * t_len * jl].copy_from_slice(j)
                        }
                        None => all_jac = false,
                    }
                    self.stats.newton_iters += reply.iterations as u64;
                    if reply.warm_started {
                        self.stats.warm_started += 1;
                    }
                    if reply.path == EvalPath::SequentialFallback {
                        self.stats.fallbacks += 1;
                    }
                }
                (ys, if all_jac { Some((jac, structure)) } else { None }, lambdas)
            }
        }
    }

    /// Forward + backward on explicit rows; does NOT touch the optimizer.
    /// Public so tests can compare the Seq and Deer gradients directly.
    ///
    /// Stacked models run one fused solve per layer going up
    /// ([`TrainLoop::forward_layer`]) and chain the backward pass going
    /// down: layer `l`'s input cotangents become layer `l − 1`'s `gs`.
    pub fn grad_minibatch(&mut self, rows: &[usize]) -> MinibatchGrad {
        let b = rows.len();
        let t_len = self.data.ds.t;
        let layers = self.model.layers();
        let n_out = self.model.state_dim();
        let (xs, labels) = self.data.ds.gather(rows);

        // ---- forward: one fused solve per layer, bottom-up ----
        let fwd_start = Instant::now();
        let mut layer_ys: Vec<Vec<f32>> = Vec::with_capacity(layers);
        let mut layer_jac: Vec<Option<(Vec<f32>, JacobianStructure)>> =
            Vec::with_capacity(layers);
        let mut layer_lambdas: Vec<Vec<f32>> = Vec::with_capacity(layers);
        for l in 0..layers {
            let (ys_l, jac_l, lam_l) = {
                let input: &[f32] = if l == 0 { &xs } else { &layer_ys[l - 1] };
                self.forward_layer(l, rows, input, b)
            };
            layer_ys.push(ys_l);
            layer_jac.push(jac_l);
            layer_lambdas.push(lam_l);
        }
        let fwd_secs = fwd_start.elapsed().as_secs_f64();

        // ---- loss + head gradients + last-layer trajectory cotangents ----
        let mut gs = vec![0.0f32; b * t_len * n_out];
        let mut grad = vec![0.0f32; self.model.num_params()];
        let pc = self.model.num_cell_params();
        let ys_last = layer_ys.last().expect("≥1 layer");
        let (loss, acc) = {
            let (_, head_tail) = grad.split_at_mut(pc);
            match &self.data.targets {
                None => {
                    let (l, a) = self.model.ce_loss_grad(
                        ys_last,
                        &labels,
                        t_len,
                        Some((&mut gs[..], head_tail)),
                    );
                    (l, Some(a))
                }
                Some(tg) => {
                    let mut targets = Vec::with_capacity(b * tg.k);
                    for &row in rows {
                        targets.extend_from_slice(&tg.values[row * tg.k..(row + 1) * tg.k]);
                    }
                    let l = self.model.mse_loss_grad(
                        ys_last,
                        &targets,
                        t_len,
                        Some((&mut gs[..], head_tail)),
                    );
                    (l, None)
                }
            }
        };

        // ---- backward: chain gs down the stack, top layer first ----
        let bwd_start = Instant::now();
        // `gs_cur` is the cotangent of layer l's OUTPUT trajectory; after
        // processing layer l it becomes the layer's input cotangent — which
        // is exactly layer l − 1's output cotangent.
        let mut gs_cur = gs;
        for l in (0..layers).rev() {
            let cell = self.model.cell(l);
            let n = cell.state_dim();
            let m = cell.input_dim();
            let input: &[f32] = if l == 0 { &xs } else { &layer_ys[l - 1] };
            let ys = &layer_ys[l];
            let mut h0s = vec![0.0f32; b * n];
            if cell.ode_view().is_some() {
                for s in 0..b {
                    h0s[s * n..(s + 1) * n]
                        .copy_from_slice(&input[s * t_len * m..s * t_len * m + n]);
                }
            }
            let want_dx = l > 0;
            let range = self.model.layer_param_range(l);
            // Continuous-time layer under a parallel arm: the exact eq.-10
            // reverse — one dual scan over the discretized linearization
            // with the DISCRETIZE-phase (expm/φ₁) VJP folded in. The Seq
            // arm instead falls through to BPTT, which differentiates the
            // RK4 flow map step by step via the cell's `vjp_step`.
            if self.cfg.mode_for_layer(l) != ForwardMode::Seq {
                if let Some(view) = cell.ode_view() {
                    let l_nodes = t_len + 1;
                    let ln = l_nodes * n;
                    let sys = FieldSystem::new(view.field);
                    let ts: Vec<f32> =
                        (0..l_nodes).map(|i| view.dt * i as f32).collect();
                    // rebuild the full node grid: node 0 = the IC, nodes
                    // 1..=T = the forward trajectory; output cotangents
                    // land on nodes 1..=T (the IC carries no loss term)
                    let mut ys_full = vec![0.0f32; b * ln];
                    let mut gs_all = vec![0.0f32; b * ln];
                    for s in 0..b {
                        ys_full[s * ln..s * ln + n]
                            .copy_from_slice(&h0s[s * n..(s + 1) * n]);
                        ys_full[s * ln + n..(s + 1) * ln]
                            .copy_from_slice(&ys[s * t_len * n..(s + 1) * t_len * n]);
                        gs_all[s * ln + n..(s + 1) * ln]
                            .copy_from_slice(&gs_cur[s * t_len * n..(s + 1) * t_len * n]);
                    }
                    let back = deer_ode_backward_batch(
                        &sys,
                        &ts,
                        &ys_full,
                        &gs_all,
                        view.interp,
                        self.cfg.threads,
                        b,
                    );
                    grad[range].copy_from_slice(&back.dtheta);
                    // single-layer only (validated in `new`): nothing below
                    // to chain dy0 into
                    continue;
                }
            }
            match self.cfg.mode_for_layer(l) {
                ForwardMode::Seq => {
                    // BPTT, sequential per sequence (the baseline's backward)
                    let mut dtheta = vec![0.0f32; cell.num_params()];
                    let mut dxs: Option<Vec<f32>> =
                        if want_dx { Some(vec![0.0f32; b * t_len * m]) } else { None };
                    for s in 0..b {
                        let dx_s = dxs
                            .as_mut()
                            .map(|d| &mut d[s * t_len * m..(s + 1) * t_len * m]);
                        seq_rnn_backward_io(
                            cell,
                            &h0s[s * n..(s + 1) * n],
                            &input[s * t_len * m..(s + 1) * t_len * m],
                            &ys[s * t_len * n..(s + 1) * t_len * n],
                            &gs_cur[s * t_len * n..(s + 1) * t_len * n],
                            &mut dtheta,
                            dx_s,
                        );
                    }
                    grad[range].copy_from_slice(&dtheta);
                    if let Some(d) = dxs {
                        gs_cur = d;
                    }
                }
                ForwardMode::Deer
                | ForwardMode::QuasiDeer
                | ForwardMode::Hybrid
                | ForwardMode::Elk
                | ForwardMode::QuasiElk => {
                    // Hybrid differentiates with the exact dense dual scan
                    // (its QuasiDeer-style forward savings are forward-only).
                    let structure = match &layer_jac[l] {
                        Some((_, st)) => *st,
                        None => effective_structure(
                            cell,
                            match self.cfg.mode_for_layer(l) {
                                ForwardMode::QuasiDeer | ForwardMode::QuasiElk => {
                                    JacobianMode::DiagonalApprox
                                }
                                _ => JacobianMode::Full,
                            },
                        ),
                    };
                    let jac_ref: Option<&[f32]> = layer_jac[l].as_ref().map(|(j, _)| &j[..]);
                    // ELK arms (or an explicit --lambda0 on a Deer arm)
                    // re-solve the damped dual with each row's last
                    // accepted λ; all-zero λ routes to the plain scan
                    // bitwise, so this is a no-op outside damping.
                    let damping: Option<&[f32]> = if self.cfg.lambda0_for_layer(l).is_some() {
                        Some(&layer_lambdas[l])
                    } else {
                        None
                    };
                    let g = if self.cfg.shards > 1 {
                        // window-chained dual scan: recomputes Jacobians one
                        // window at a time, so peak backward memory matches
                        // the forward's O(B·W·jac); bitwise-equal to the
                        // full reverse scan at threads = 1
                        deer_rnn_backward_sharded(
                            cell,
                            &h0s,
                            input,
                            ys,
                            &gs_cur,
                            structure,
                            self.cfg.threads,
                            b,
                            self.cfg.shards,
                            want_dx,
                        )
                    } else {
                        deer_rnn_backward_batch_damped_io(
                            cell,
                            &h0s,
                            input,
                            ys,
                            &gs_cur,
                            jac_ref,
                            structure,
                            damping,
                            self.cfg.threads,
                            b,
                            want_dx,
                        )
                    };
                    grad[range].copy_from_slice(&g.dtheta);
                    if let Some(d) = g.dxs {
                        gs_cur = d;
                    }
                }
            }
        }
        let bwd_secs = bwd_start.elapsed().as_secs_f64();

        MinibatchGrad { grad, loss, acc, fwd_secs, bwd_secs }
    }

    /// One optimizer step on the next shuffled minibatch.
    pub fn step(&mut self) -> StepStats {
        let _step_span = crate::telemetry::span_with(
            "train_step",
            vec![(
                "step",
                crate::telemetry::ArgValue::Num((self.stats.steps + 1) as f64),
            )],
        );
        let rows = self.next_batch();
        let mb = self.grad_minibatch(&rows);
        self.opt.step(&mut self.params, &mb.grad);
        self.model.load_params(&self.params);
        self.stats.steps += 1;
        self.stats.fwd_secs += mb.fwd_secs;
        self.stats.bwd_secs += mb.bwd_secs;
        let stats = StepStats {
            step: self.stats.steps,
            loss: mb.loss,
            acc: mb.acc,
            fwd_secs: mb.fwd_secs,
            bwd_secs: mb.bwd_secs,
        };
        self.curve.push(CurvePoint {
            step: self.stats.steps,
            wall_secs: self.started.elapsed().as_secs_f64(),
            loss: mb.loss,
            acc: mb.acc,
        });
        stats
    }

    /// Run `steps` optimizer steps; returns the last step's stats.
    pub fn run(&mut self, steps: usize) -> Option<StepStats> {
        let mut last = None;
        for _ in 0..steps {
            last = Some(self.step());
        }
        last
    }

    /// Evaluate a split with the exact sequential forward (no gradients, no
    /// cache pollution): returns `(mean loss, accuracy)` — accuracy `None`
    /// for regression tasks. Stacked models run the whole stack
    /// sequentially, layer by layer.
    pub fn eval(&self, split: Split) -> (f64, Option<f64>) {
        let t_len = self.data.ds.t;
        let mut loss_sum = 0.0f64;
        let mut acc_sum = 0.0f64;
        let mut rows = 0usize;
        for chunk in self.data.ds.batches(split, 1) {
            let row = chunk[0];
            let mut ys = self.data.ds.row(row).to_vec();
            for l in 0..self.model.layers() {
                let cell = self.model.cell(l);
                // ODE layers integrate from the row's first frame
                let h0 = if cell.ode_view().is_some() {
                    ys[..cell.state_dim()].to_vec()
                } else {
                    vec![0.0f32; cell.state_dim()]
                };
                ys = seq_rnn(cell, &h0, &ys);
            }
            match &self.data.targets {
                None => {
                    let (l, a) =
                        self.model
                            .ce_loss_grad(&ys, &[self.data.ds.labels[row]], t_len, None);
                    loss_sum += l;
                    acc_sum += a;
                }
                Some(tg) => {
                    let l = self.model.mse_loss_grad(
                        &ys,
                        &tg.values[row * tg.k..(row + 1) * tg.k],
                        t_len,
                        None,
                    );
                    loss_sum += l;
                }
            }
            rows += 1;
        }
        let rows = rows.max(1) as f64;
        (
            loss_sum / rows,
            self.data.targets.is_none().then_some(acc_sum / rows),
        )
    }
}

/// Synthetic EigenWorms classification task (§4.3 substrate): `rows`
/// sequences of length `t` with 6 channels, 5 classes, 70/15/15 split.
pub fn worms_task(rows: usize, t: usize, seed: u64) -> TrainData {
    let (xs, labels) = crate::data::worms::generate(rows, t, seed);
    TrainData {
        ds: Dataset::new(xs, labels, t, crate::data::worms::CHANNELS),
        targets: None,
    }
}

/// Two-body energy-regression task (§4.2 substrate): the model reads the
/// 8-channel state trajectory and regresses the (conserved) total energy —
/// a mean-pool + MSE workload for the regression head.
pub fn twobody_task(rows: usize, t: usize, seed: u64) -> TrainData {
    let xs = crate::data::twobody::generate(rows, 10.0, t, seed);
    let mut targets = Vec::with_capacity(rows);
    for r in 0..rows {
        let s0: Vec<f64> = xs[r * t * crate::data::twobody::STATE
            ..r * t * crate::data::twobody::STATE + crate::data::twobody::STATE]
            .iter()
            .map(|&v| v as f64)
            .collect();
        targets.push(crate::data::twobody::energy(&s0) as f32);
    }
    TrainData {
        ds: Dataset::new(xs, vec![0; rows], t, crate::data::twobody::STATE),
        targets: Some(Targets { k: 1, values: targets }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cells::Gru;
    use crate::train::native::model::Readout;

    fn tiny_loop(mode: ForwardMode, seed: u64) -> TrainLoop<Gru<f32>> {
        let mut rng = Rng::new(seed);
        let cell: Gru<f32> = Gru::new(4, crate::data::worms::CHANNELS, &mut rng);
        let model = Model::new(cell, crate::data::worms::CLASSES, Readout::LastState, &mut rng);
        let data = worms_task(16, 24, 7);
        TrainLoop::new(
            model,
            data,
            TrainConfig { mode, batch: 4, seed, ..Default::default() },
        )
        .unwrap()
    }

    /// Regression task whose rows are continuous-state trajectories: only
    /// the FIRST frame matters to an ODE layer (it is the initial
    /// condition); the target is a smooth function of that frame.
    fn ode_task(rows: usize, t: usize, n: usize, seed: u64) -> TrainData {
        let mut rng = Rng::new(seed);
        let mut xs = vec![0.0f32; rows * t * n];
        rng.fill_normal(&mut xs, 0.4);
        let targets: Vec<f32> = (0..rows)
            .map(|r| xs[r * t * n..r * t * n + n].iter().sum())
            .collect();
        TrainData {
            ds: Dataset::new(xs, vec![0; rows], t, n),
            targets: Some(Targets { k: 1, values: targets }),
        }
    }

    fn ode_loop(
        mode: ForwardMode,
        seed: u64,
    ) -> TrainLoop<crate::cells::OdeCell<f32, crate::cells::MlpField<f32>>> {
        let mut rng = Rng::new(seed);
        let field = crate::cells::MlpField::new(4, 8, &mut rng);
        let cell = crate::cells::OdeCell::new(field, 0.005, 1, crate::deer::Interp::Midpoint);
        let model = Model::new(cell, 1, Readout::MeanPool, &mut rng);
        let data = ode_task(10, 32, 4, 11);
        TrainLoop::new(
            model,
            data,
            TrainConfig {
                mode,
                batch: 4,
                seed,
                tol_override: Some(1e-6),
                ..Default::default()
            },
        )
        .unwrap()
    }

    /// The tentpole acceptance gate: the continuous-time layer trained
    /// through the fused DEER-ODE engine must produce per-minibatch
    /// gradients matching BPTT-through-RK4 (the Seq arm) to rel-err
    /// < 1e-3 — the two arms discretize the same flow (midpoint
    /// exponential-integrator fixed point vs. the RK4 map), so they agree
    /// up to O(dt²) truncation.
    #[test]
    fn ode_seq_and_deer_gradients_agree() {
        let mut a = ode_loop(ForwardMode::Seq, 3);
        let mut d = ode_loop(ForwardMode::Deer, 3);
        let rows: Vec<usize> = (0..4).collect();
        let ga = a.grad_minibatch(&rows);
        let gd = d.grad_minibatch(&rows);
        assert!(
            (ga.loss - gd.loss).abs() <= 1e-3 * ga.loss.abs().max(1e-6),
            "loss mismatch: seq {} vs deer {}",
            ga.loss,
            gd.loss
        );
        let num: f64 = ga
            .grad
            .iter()
            .zip(&gd.grad)
            .map(|(&x, &y)| ((x - y) as f64).powi(2))
            .sum::<f64>()
            .sqrt();
        let den: f64 = ga.grad.iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt();
        assert!(den > 0.0, "degenerate zero gradient");
        assert!(
            num / den < 1e-3,
            "ODE gradient rel-err {} (num {num}, den {den})",
            num / den
        );
    }

    #[test]
    fn ode_deer_trains_through_fused_solves() {
        let mut tl = ode_loop(ForwardMode::Deer, 5);
        let last = tl.run(3).unwrap();
        assert!(last.loss.is_finite());
        assert_eq!(tl.stats.steps, 3);
        // one fused ODE solve per minibatch, no sequential fallbacks
        assert!(tl.stats.batched_solves >= 3, "{:?}", tl.stats);
        assert_eq!(tl.stats.fallbacks, 0, "{:?}", tl.stats);
        let (loss, acc) = tl.eval(Split::Test);
        assert!(loss.is_finite());
        assert!(acc.is_none(), "regression task");
    }

    #[test]
    fn ode_misconfigurations_rejected() {
        // quasi/hybrid/elk arms have no continuous analogue
        let mut rng = Rng::new(2);
        let mk_model = |rng: &mut Rng| {
            let field = crate::cells::MlpField::new(4, 8, rng);
            let cell =
                crate::cells::OdeCell::new(field, 0.01, 1, crate::deer::Interp::Midpoint);
            Model::new(cell, 1, Readout::MeanPool, rng)
        };
        let bad_mode = TrainLoop::new(
            mk_model(&mut rng),
            ode_task(10, 16, 4, 11),
            TrainConfig { mode: ForwardMode::QuasiDeer, batch: 4, ..Default::default() },
        );
        assert!(bad_mode.is_err());
        // sharding is a discrete-path construction
        let bad_shards = TrainLoop::new(
            mk_model(&mut rng),
            ode_task(10, 16, 4, 11),
            TrainConfig { mode: ForwardMode::Deer, batch: 4, shards: 2, ..Default::default() },
        );
        assert!(bad_shards.is_err());
        // stacked ODE layers have no inter-layer input grid
        let mut rng2 = Rng::new(3);
        let cells: Vec<_> = (0..2)
            .map(|_| {
                let field = crate::cells::MlpField::new(4, 8, &mut rng2);
                crate::cells::OdeCell::new(field, 0.01, 1, crate::deer::Interp::Midpoint)
            })
            .collect();
        let stacked = Model::stacked(cells, 1, Readout::MeanPool, &mut rng2).unwrap();
        let bad_stack = TrainLoop::new(
            stacked,
            ode_task(10, 16, 4, 11),
            TrainConfig { mode: ForwardMode::Deer, batch: 4, ..Default::default() },
        );
        assert!(bad_stack.is_err());
    }

    fn stacked_loop(mode: ForwardMode, layers: usize, seed: u64) -> TrainLoop<Gru<f32>> {
        let mut rng = Rng::new(seed);
        let cells: Vec<Gru<f32>> = (0..layers)
            .map(|l| {
                let m = if l == 0 { crate::data::worms::CHANNELS } else { 4 };
                Gru::new(4, m, &mut rng)
            })
            .collect();
        let model =
            Model::stacked(cells, crate::data::worms::CLASSES, Readout::LastState, &mut rng)
                .unwrap();
        let data = worms_task(16, 24, 7);
        TrainLoop::new(
            model,
            data,
            TrainConfig { mode, batch: 4, seed, ..Default::default() },
        )
        .unwrap()
    }

    #[test]
    fn steps_advance_and_curve_grows() {
        let mut tl = tiny_loop(ForwardMode::Seq, 1);
        let s = tl.run(3).unwrap();
        assert_eq!(s.step, 3);
        assert_eq!(tl.curve.len(), 3);
        assert!(tl.curve.iter().all(|p| p.loss.is_finite()));
        assert_eq!(tl.stats.steps, 3);
        assert!(tl.stats.epochs >= 1);
    }

    #[test]
    fn deer_mode_issues_one_fused_solve_per_step() {
        let mut tl = tiny_loop(ForwardMode::Deer, 2);
        tl.run(4).unwrap();
        assert_eq!(tl.stats.batched_solves, 4, "one fused solve per minibatch");
        assert_eq!(tl.stats.sequences_solved, 16);
        assert_eq!(tl.stats.fallbacks, 0);
    }

    #[test]
    fn warm_start_kicks_in_after_first_epoch() {
        // 16 train-rows... train split of 16 rows = 11; batch 4 → ~3 steps
        // per epoch; by step 7 every row has been revisited at least once.
        let mut tl = tiny_loop(ForwardMode::Deer, 3);
        tl.run(8).unwrap();
        assert!(
            tl.stats.warm_started > 0,
            "revisited rows must warm-start from the trajectory cache"
        );
        assert!(tl.cache_hit_rate() > 0.0);
    }

    #[test]
    fn params_round_trip_through_optimizer() {
        let mut tl = tiny_loop(ForwardMode::Seq, 4);
        let before = tl.params().to_vec();
        tl.step();
        let after = tl.params().to_vec();
        assert_ne!(before, after, "optimizer must move the parameters");
        // the model's own view agrees with the flat vector
        let mut flat = vec![0.0f32; tl.model.num_params()];
        tl.model.write_params(&mut flat);
        assert_eq!(flat, after);
    }

    /// Stacked dispatch invariant: L layers → exactly L fused solves per
    /// minibatch, one per layer, and every layer's cache warm-starts after
    /// the first epoch.
    #[test]
    fn stacked_deer_issues_one_fused_solve_per_layer() {
        let layers = 2;
        let mut tl = stacked_loop(ForwardMode::Deer, layers, 11);
        let steps = 6;
        tl.run(steps).unwrap();
        assert_eq!(
            tl.stats.batched_solves,
            (steps * layers) as u64,
            "one fused solve per LAYER per minibatch"
        );
        assert_eq!(tl.stats.solves_per_layer.len(), layers);
        for (l, &s) in tl.stats.solves_per_layer.iter().enumerate() {
            assert_eq!(s, steps as u64, "layer {l} solve count");
        }
        assert_eq!(tl.stats.sequences_solved, (steps * layers * 4) as u64);
        assert_eq!(tl.stats.fallbacks, 0);
        assert!(tl.stats.warm_started > 0, "layer caches must warm-start on revisits");
        assert!(tl.curve.iter().all(|p| p.loss.is_finite()));
    }

    /// Misconfigurations are clean errors, not aborts.
    #[test]
    fn new_rejects_bad_configs_without_panicking() {
        let mut rng = Rng::new(12);
        let cell: Gru<f32> = Gru::new(4, crate::data::worms::CHANNELS, &mut rng);
        let model = Model::new(cell, crate::data::worms::CLASSES, Readout::LastState, &mut rng);
        // batch larger than the train split (the old loop.rs:226 panic)
        let err = TrainLoop::new(
            model.clone(),
            worms_task(8, 16, 3), // train split = 6 rows
            TrainConfig { batch: 7, ..Default::default() },
        )
        .err()
        .expect("undersized split must be an error");
        assert!(err.to_string().contains("train split"), "{err}");
        // zero batch
        assert!(TrainLoop::new(
            model.clone(),
            worms_task(8, 16, 3),
            TrainConfig { batch: 0, ..Default::default() },
        )
        .is_err());
        // out-of-range labels (the old Model assert)
        let mut data = worms_task(8, 16, 3);
        data.ds.labels[2] = 99;
        let err = TrainLoop::new(model, data, TrainConfig { batch: 2, ..Default::default() })
            .err()
            .expect("bad label must be an error");
        assert!(err.to_string().contains("label"), "{err}");
    }

    /// An LR schedule changes the trajectory; the constant default does not.
    #[test]
    fn lr_schedule_wiring() {
        use crate::train::native::opt::LrSchedule;
        let mut base = tiny_loop(ForwardMode::Seq, 13);
        let mut cfg_sched = TrainConfig { mode: ForwardMode::Seq, batch: 4, seed: 13, ..Default::default() };
        cfg_sched.lr_schedule = LrSchedule::Step { every: 1, gamma: 0.0, warmup: 0 };
        let mut rng = Rng::new(13);
        let cell: Gru<f32> = Gru::new(4, crate::data::worms::CHANNELS, &mut rng);
        let model = Model::new(cell, crate::data::worms::CLASSES, Readout::LastState, &mut rng);
        let mut sched = TrainLoop::new(model, worms_task(16, 24, 7), cfg_sched).unwrap();
        let p0 = sched.params().to_vec();
        base.step();
        sched.step(); // factor 0 at step 1 → params frozen
        assert_eq!(sched.params(), &p0[..], "zero-factor schedule must freeze params");
        assert_ne!(base.params(), &p0[..], "constant-schedule baseline must move");
    }

    /// Checkpoint round trip: params + optimizer state survive save/load
    /// bitwise and training resumes identically.
    #[test]
    fn checkpoint_round_trip_resumes_identically() {
        let dir = std::env::temp_dir().join(format!("deer_ckpt_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("loop_roundtrip.json");
        let mut a = tiny_loop(ForwardMode::Seq, 14);
        a.run(3).unwrap();
        a.save_checkpoint(&path).unwrap();
        let after_save = a.params().to_vec();

        let mut b = tiny_loop(ForwardMode::Seq, 14);
        b.load_checkpoint(&path).unwrap();
        assert_eq!(b.params(), &after_save[..], "params must round-trip bitwise");
        assert_eq!(b.opt.steps(), a.opt.steps(), "step counter must round-trip");
        assert_eq!(
            b.stats.steps, a.stats.steps,
            "curve numbering must resume where the checkpoint left off"
        );
        // both loops continue from the same state with the same data order
        // (b's rng/order were never advanced — rebuild a's schedule state)
        let rows: Vec<usize> = (0..4).collect();
        let ga = a.grad_minibatch(&rows);
        let gb = b.grad_minibatch(&rows);
        assert_eq!(ga.grad, gb.grad, "post-restore gradients must match bitwise");
        std::fs::remove_file(&path).ok();
    }

    /// A checkpoint saved under one LR schedule refuses to load into a loop
    /// running another — a silent schedule swap would jump the learning
    /// rate discontinuously at the restored step counter.
    #[test]
    fn checkpoint_rejects_schedule_mismatch() {
        use crate::train::native::opt::LrSchedule;
        let dir = std::env::temp_dir().join(format!("deer_ckpt_sched_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("cosine.json");
        let mut rng = Rng::new(15);
        let cell: Gru<f32> = Gru::new(4, crate::data::worms::CHANNELS, &mut rng);
        let model = Model::new(cell, crate::data::worms::CLASSES, Readout::LastState, &mut rng);
        let cfg = TrainConfig {
            mode: ForwardMode::Seq,
            batch: 4,
            seed: 15,
            lr_schedule: LrSchedule::Cosine { total: 50, warmup: 5 },
            ..Default::default()
        };
        let mut a = TrainLoop::new(model, worms_task(16, 24, 7), cfg).unwrap();
        a.step();
        a.save_checkpoint(&path).unwrap();

        let mut constant = tiny_loop(ForwardMode::Seq, 15);
        let err = constant.load_checkpoint(&path).unwrap_err();
        assert!(err.to_string().contains("lr-schedule"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn regression_task_trains() {
        let mut rng = Rng::new(5);
        let cell: Gru<f32> = Gru::new(4, crate::data::twobody::STATE, &mut rng);
        let model = Model::new(cell, 1, Readout::MeanPool, &mut rng);
        let data = twobody_task(12, 32, 9);
        let mut tl = TrainLoop::new(
            model,
            data,
            TrainConfig { mode: ForwardMode::Deer, batch: 4, ..Default::default() },
        )
        .unwrap();
        let s = tl.run(3).unwrap();
        assert!(s.loss.is_finite());
        assert!(s.acc.is_none(), "regression reports no accuracy");
        let (eval_loss, eval_acc) = tl.eval(Split::Val);
        assert!(eval_loss.is_finite());
        assert!(eval_acc.is_none());
    }

    /// Trainer-level half of the shard agreement pin (ISSUE: T = 8k,
    /// S = 4): with exact stitching, `reuse_jacobians = false` (so both
    /// arms differentiate along the converged trajectory) and one thread,
    /// the sharded trainer's loss AND flat gradient are bitwise-identical
    /// to the unsharded trainer's — and whole optimizer steps stay bitwise.
    #[test]
    fn sharded_trainer_matches_unsharded_bitwise_at_8k() {
        let t = 8192;
        let mk = |shards: usize| {
            let mut rng = Rng::new(21);
            let cell: Gru<f32> = Gru::new(3, crate::data::worms::CHANNELS, &mut rng);
            let model =
                Model::new(cell, crate::data::worms::CLASSES, Readout::LastState, &mut rng);
            TrainLoop::new(
                model,
                worms_task(6, t, 5),
                TrainConfig {
                    mode: ForwardMode::Deer,
                    batch: 2,
                    seed: 21,
                    shards,
                    reuse_jacobians: false,
                    ..Default::default()
                },
            )
            .unwrap()
        };
        let mut plain = mk(1);
        let mut sharded = mk(4);
        let rows: Vec<usize> = vec![0, 1];
        let ga = plain.grad_minibatch(&rows);
        let gb = sharded.grad_minibatch(&rows);
        assert_eq!(ga.loss, gb.loss, "sharded forward must reproduce the loss bitwise");
        assert_eq!(ga.grad, gb.grad, "sharded backward must reproduce the gradient bitwise");
        assert_eq!(sharded.stats.shard_solves, 1);
        assert_eq!(sharded.stats.shard_windows, (rows.len() * 4) as u64);
        assert_eq!(sharded.stats.stitch_iters, 1, "exact stitching = one outer pass");
        assert_eq!(plain.stats.shard_solves, 0);
        let sa = plain.step();
        let sb = sharded.step();
        assert_eq!(sa.loss, sb.loss);
        assert_eq!(plain.params(), sharded.params(), "optimizer steps stay bitwise");
    }

    /// Satellite: per-layer `--mode deer,seq` — layer 0 runs fused DEER,
    /// layer 1 runs sequential BPTT — trains with the dispatch counters
    /// proving the split, and rejects a wrong-length mode list.
    #[test]
    fn mixed_mode_stack_trains_with_split_dispatch() {
        let layers = 2;
        let mut rng = Rng::new(31);
        let cells: Vec<Gru<f32>> = (0..layers)
            .map(|l| {
                let m = if l == 0 { crate::data::worms::CHANNELS } else { 4 };
                Gru::new(4, m, &mut rng)
            })
            .collect();
        let model =
            Model::stacked(cells, crate::data::worms::CLASSES, Readout::LastState, &mut rng)
                .unwrap();
        let cfg = TrainConfig {
            mode: ForwardMode::Deer,
            layer_modes: Some(ForwardMode::parse_modes("deer,seq").unwrap()),
            batch: 4,
            seed: 31,
            ..Default::default()
        };
        let mut tl = TrainLoop::new(model.clone(), worms_task(16, 24, 7), cfg).unwrap();
        let steps = 3;
        tl.run(steps).unwrap();
        assert!(tl.curve.iter().all(|p| p.loss.is_finite()));
        assert_eq!(tl.stats.solves_per_layer[0], steps as u64, "layer 0 is fused DEER");
        assert_eq!(tl.stats.solves_per_layer[1], 0, "layer 1 is sequential BPTT");
        assert_eq!(tl.stats.batched_solves, steps as u64);
        // wrong-length list is a clean error
        let bad = TrainConfig {
            layer_modes: Some(vec![ForwardMode::Deer]),
            batch: 4,
            ..Default::default()
        };
        let err = TrainLoop::new(model, worms_task(16, 24, 7), bad).unwrap_err();
        assert!(err.to_string().contains("per-layer"), "{err}");
    }

    /// `--shards` composes with the damped arms only by rejection: the
    /// sharded backward is undamped-only, so ELK + shards is a clean error.
    #[test]
    fn shards_reject_damped_arms() {
        let mut rng = Rng::new(33);
        let cell: Gru<f32> = Gru::new(4, crate::data::worms::CHANNELS, &mut rng);
        let model = Model::new(cell, crate::data::worms::CLASSES, Readout::LastState, &mut rng);
        let err = TrainLoop::new(
            model.clone(),
            worms_task(16, 24, 7),
            TrainConfig { mode: ForwardMode::Elk, shards: 4, batch: 4, ..Default::default() },
        )
        .unwrap_err();
        assert!(err.to_string().contains("shards"), "{err}");
        let err = TrainLoop::new(
            model.clone(),
            worms_task(16, 24, 7),
            TrainConfig {
                mode: ForwardMode::Deer,
                damping_lambda0: Some(1.0),
                shards: 2,
                batch: 4,
                ..Default::default()
            },
        )
        .unwrap_err();
        assert!(err.to_string().contains("shards"), "{err}");
        assert!(TrainLoop::new(
            model,
            worms_task(16, 24, 7),
            TrainConfig { mode: ForwardMode::Seq, shards: 0, batch: 4, ..Default::default() },
        )
        .is_err());
    }

    #[test]
    fn forward_mode_parse() {
        assert_eq!(ForwardMode::parse("seq").unwrap(), ForwardMode::Seq);
        assert_eq!(ForwardMode::parse("deer").unwrap(), ForwardMode::Deer);
        assert_eq!(ForwardMode::parse("quasi").unwrap(), ForwardMode::QuasiDeer);
        assert_eq!(ForwardMode::parse("hybrid").unwrap(), ForwardMode::Hybrid);
        assert_eq!(ForwardMode::parse("elk").unwrap(), ForwardMode::Elk);
        assert_eq!(ForwardMode::parse("quasi-elk").unwrap(), ForwardMode::QuasiElk);
        assert_eq!(ForwardMode::parse("quasielk").unwrap(), ForwardMode::QuasiElk);
        assert!(ForwardMode::parse("xla").is_err());
    }

    /// The ELK arm trains: fused dispatch, finite loss, and its gradient
    /// matches the exact Deer arm to forward-tolerance level — by the time
    /// the damped solve converges λ has shrunk to near zero, so the damped
    /// dual is a tolerance-level perturbation of the exact one.
    #[test]
    fn elk_mode_trains_and_matches_deer_gradient() {
        let mut tl_e = tiny_loop(ForwardMode::Elk, 8);
        let mut tl_d = tiny_loop(ForwardMode::Deer, 8);
        assert_eq!(tl_e.cfg.effective_lambda0(), Some(1.0));
        assert_eq!(tl_d.cfg.effective_lambda0(), None);
        let rows: Vec<usize> = vec![0, 1, 2, 3];
        let ge = tl_e.grad_minibatch(&rows);
        let gd = tl_d.grad_minibatch(&rows);
        assert!(ge.loss.is_finite());
        assert!((ge.loss - gd.loss).abs() < 1e-3, "{} vs {}", ge.loss, gd.loss);
        for (a, b) in ge.grad.iter().zip(gd.grad.iter()) {
            assert!((a - b).abs() < 1e-2, "elk vs deer gradient: {a} vs {b}");
        }
        let s = tl_e.run(3).unwrap();
        assert!(s.loss.is_finite());
        assert_eq!(tl_e.stats.fallbacks, 0);
        assert_eq!(tl_e.stats.diverged_nonfinite, 0);
        assert_eq!(tl_e.stats.diverged_lambda_exhausted, 0);
    }

    /// Quasi-ELK replaces the fixed trust radius with adaptive damping —
    /// no step_clamp configured, still trains to a finite loss with one
    /// fused solve per minibatch.
    #[test]
    fn quasi_elk_trains_without_step_clamp() {
        let mut tl = tiny_loop(ForwardMode::QuasiElk, 9);
        assert!(tl.cfg.step_clamp.is_none(), "damping subsumes the trust radius");
        assert_eq!(tl.cfg.effective_lambda0(), Some(1.0));
        let s = tl.run(3).unwrap();
        assert!(s.loss.is_finite());
        assert_eq!(tl.stats.batched_solves, 3, "one fused solve per minibatch");
    }

    /// `--verbose` observability: a verbose ELK step runs end to end (the
    /// per-sequence trace printing must not disturb training).
    #[test]
    fn verbose_elk_step_runs() {
        let mut rng = Rng::new(16);
        let cell: Gru<f32> = Gru::new(4, crate::data::worms::CHANNELS, &mut rng);
        let model = Model::new(cell, crate::data::worms::CLASSES, Readout::LastState, &mut rng);
        let mut tl = TrainLoop::new(
            model,
            worms_task(16, 24, 7),
            TrainConfig {
                mode: ForwardMode::Elk,
                batch: 4,
                seed: 16,
                verbose: true,
                ..Default::default()
            },
        )
        .unwrap();
        let s = tl.step();
        assert!(s.loss.is_finite());
    }

    /// The hybrid arm trains: one fused solve per minibatch, finite loss,
    /// and its per-minibatch gradient matches the exact Deer arm to
    /// forward-tolerance level (both backwards are exact dense).
    #[test]
    fn hybrid_mode_trains_and_matches_deer_gradient() {
        let mut tl_h = tiny_loop(ForwardMode::Hybrid, 6);
        let mut tl_d = tiny_loop(ForwardMode::Deer, 6);
        let rows: Vec<usize> = vec![0, 1, 2, 3];
        let gh = tl_h.grad_minibatch(&rows);
        let gd = tl_d.grad_minibatch(&rows);
        assert!(gh.loss.is_finite());
        assert!((gh.loss - gd.loss).abs() < 1e-3, "{} vs {}", gh.loss, gd.loss);
        for (a, b) in gh.grad.iter().zip(gd.grad.iter()) {
            assert!((a - b).abs() < 1e-2, "hybrid vs deer gradient: {a} vs {b}");
        }
        let s = tl_h.run(3).unwrap();
        assert!(s.loss.is_finite());
        assert_eq!(tl_h.stats.batched_solves, 4, "one fused solve per minibatch");
        assert_eq!(tl_h.stats.fallbacks, 0);
    }
}
