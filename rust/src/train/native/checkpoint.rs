//! JSON checkpoints of the native trainer's state.
//!
//! A checkpoint captures the OPTIMIZER state: the flat `[cells… | head]`
//! parameter vector, both Adam moment vectors and the step counter — all
//! of which round-trip bitwise through
//! [`crate::train::native::TrainLoop::load_checkpoint`]. The data-stream
//! state (shuffle RNG, in-epoch order, epoch counter) is NOT captured: a
//! resumed run continues from the exact same weights and optimizer
//! trajectory but draws a fresh shuffle, so it is statistically — not
//! bitwise — equivalent to the uninterrupted run. Checkpoints also seed
//! solver fixtures with *trained* weights (the ROADMAP's ill-conditioned
//! fixture follow-up: trained cells stress the Newton solve in ways
//! random inits don't) via [`load_cell_params`].
//!
//! Format (`deer-checkpoint-v1`): one JSON object via [`crate::util::json`]
//! — f32 values are serialized through f64, which is exact in both
//! directions, so round trips are bitwise.

use std::path::Path;

use crate::cells::CellGrad;
use crate::util::err::{Context, Result};
use crate::util::json::{self, Json};
use crate::{anyhow, bail};

use super::opt::Adam;

/// A parsed checkpoint (see the module docs for the format).
#[derive(Debug, Clone)]
pub struct Checkpoint {
    /// Flat `[cells… | head]` parameter vector.
    pub params: Vec<f32>,
    /// Adam first-moment vector (same length as `params`).
    pub adam_m: Vec<f32>,
    /// Adam second-moment vector (same length as `params`).
    pub adam_v: Vec<f32>,
    /// Optimizer steps taken when the checkpoint was written.
    pub step: u64,
    /// Layer count of the model that wrote it (sanity-checked on load).
    pub layers: usize,
    /// Canonical [`super::opt::LrSchedule::spec`] string of the schedule
    /// the run was using — resumed runs validate (or adopt) it so the
    /// restored step counter keeps meaning the same LR factor. `None` for
    /// documents written before the field existed.
    pub lr_schedule: Option<String>,
}

const FORMAT: &str = "deer-checkpoint-v1";

fn f32s_to_json(v: &[f32]) -> Json {
    json::arr(v.iter().map(|&x| json::num(x as f64)).collect())
}

fn json_to_f32s(j: &Json, what: &str) -> Result<Vec<f32>> {
    let arr = j.as_arr().with_context(|| format!("checkpoint field {what} is not an array"))?;
    arr.iter()
        .enumerate()
        .map(|(i, v)| {
            v.as_f64()
                .map(|x| x as f32)
                .with_context(|| format!("checkpoint {what}[{i}] is not a number"))
        })
        .collect()
}

/// Serialize a checkpoint document.
pub fn to_json(params: &[f32], adam: &Adam<f32>, layers: usize, lr_schedule: &str) -> Json {
    let (m, v) = adam.moments();
    json::obj(vec![
        ("format", json::s(FORMAT)),
        ("layers", json::num(layers as f64)),
        ("num_params", json::num(params.len() as f64)),
        ("step", json::num(adam.steps() as f64)),
        ("lr_schedule", json::s(lr_schedule)),
        ("params", f32s_to_json(params)),
        ("adam_m", f32s_to_json(m)),
        ("adam_v", f32s_to_json(v)),
    ])
}

/// Parse a checkpoint document (format + length validation).
pub fn from_json(doc: &Json) -> Result<Checkpoint> {
    let format = doc
        .get("format")
        .and_then(|f| f.as_str())
        .context("checkpoint missing format field")?;
    if format != FORMAT {
        bail!("unsupported checkpoint format {format:?} (expected {FORMAT:?})");
    }
    let params = json_to_f32s(doc.get("params").context("checkpoint missing params")?, "params")?;
    let adam_m = json_to_f32s(doc.get("adam_m").context("checkpoint missing adam_m")?, "adam_m")?;
    let adam_v = json_to_f32s(doc.get("adam_v").context("checkpoint missing adam_v")?, "adam_v")?;
    let declared = doc
        .get("num_params")
        .and_then(|v| v.as_usize())
        .context("checkpoint missing num_params")?;
    if params.len() != declared {
        bail!("checkpoint declares {declared} params but carries {}", params.len());
    }
    if adam_m.len() != params.len() || adam_v.len() != params.len() {
        bail!(
            "checkpoint moment lengths ({}, {}) do not match params ({})",
            adam_m.len(),
            adam_v.len(),
            params.len()
        );
    }
    let step = doc.get("step").and_then(|v| v.as_f64()).context("checkpoint missing step")? as u64;
    let layers = doc
        .get("layers")
        .and_then(|v| v.as_usize())
        .context("checkpoint missing layers")?;
    let lr_schedule = doc
        .get("lr_schedule")
        .and_then(|v| v.as_str())
        .map(str::to_string);
    Ok(Checkpoint { params, adam_m, adam_v, step, layers, lr_schedule })
}

/// Write a checkpoint to `path` (parent directories are created). Refuses
/// non-finite state: the JSON writer would emit bare `NaN`/`inf` tokens
/// that [`load`] can never parse back, so a diverged run fails loudly at
/// save time instead of leaving an unrecoverable artifact.
pub fn save(
    path: &Path,
    params: &[f32],
    adam: &Adam<f32>,
    layers: usize,
    lr_schedule: &str,
) -> Result<()> {
    let (m, v) = adam.moments();
    for (what, vals) in [("params", params), ("adam_m", m), ("adam_v", v)] {
        if let Some(i) = vals.iter().position(|x| !x.is_finite()) {
            bail!(
                "refusing to checkpoint non-finite state: {what}[{i}] = {} (run diverged?)",
                vals[i]
            );
        }
    }
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("creating checkpoint dir {}", dir.display()))?;
        }
    }
    std::fs::write(path, to_json(params, adam, layers, lr_schedule).to_string())
        .with_context(|| format!("writing checkpoint {}", path.display()))
}

/// Read and validate a checkpoint from `path`.
pub fn load(path: &Path) -> Result<Checkpoint> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading checkpoint {}", path.display()))?;
    let doc = Json::parse(&text)
        .map_err(|e| anyhow!("parsing checkpoint {}: {e}", path.display()))?;
    from_json(&doc)
}

/// Rebuild a flat parameter vector's cell segment into `cell` — checkpoint
/// weights as solver fixtures: takes the FIRST layer's slice of a
/// checkpoint written by a model whose layer-0 cell has `cell.num_params()`
/// parameters.
pub fn load_cell_params<C: CellGrad<f32>>(ck: &Checkpoint, cell: &mut C) -> Result<()> {
    let pc = cell.num_params();
    if ck.params.len() < pc {
        bail!("checkpoint has {} params, cell needs {pc}", ck.params.len());
    }
    cell.load_params(&ck.params[..pc]);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::train::native::opt::AdamConfig;

    fn temp_path(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("deer_ckpt_{}_{name}", std::process::id()))
    }

    /// Save → load is bitwise for params, moments and the step counter
    /// (f32 → f64 JSON → f32 is exact).
    #[test]
    fn round_trip_is_bitwise() {
        let params: Vec<f32> = vec![0.1, -2.5e-7, 3.0e8, f32::MIN_POSITIVE, 0.333_333_34];
        let mut adam: Adam<f32> = Adam::new(5, AdamConfig::default());
        let mut p = params.clone();
        adam.step(&mut p, &[0.3, -0.1, 0.9, 1e-4, -7.0]);
        adam.step(&mut p, &[-0.2, 0.4, 0.1, 2e-4, 3.0]);
        let path = temp_path("roundtrip.json");
        save(&path, &p, &adam, 3, "cosine:200:20").unwrap();
        let ck = load(&path).unwrap();
        assert_eq!(ck.params, p);
        let (m, v) = adam.moments();
        assert_eq!(ck.adam_m, m);
        assert_eq!(ck.adam_v, v);
        assert_eq!(ck.step, 2);
        assert_eq!(ck.layers, 3);
        assert_eq!(ck.lr_schedule.as_deref(), Some("cosine:200:20"));
        std::fs::remove_file(&path).ok();
    }

    /// Diverged (non-finite) state is rejected at save time with a clear
    /// error — never written as unparseable JSON.
    #[test]
    fn rejects_non_finite_state() {
        let adam: Adam<f32> = Adam::new(3, AdamConfig::default());
        let path = temp_path("nan.json");
        let err = save(&path, &[1.0, f32::NAN, 3.0], &adam, 1, "constant").unwrap_err();
        assert!(err.to_string().contains("non-finite"), "{err}");
        assert!(!path.exists(), "no file may be written for non-finite state");
        let err = save(&path, &[1.0, f32::INFINITY, 3.0], &adam, 1, "constant").unwrap_err();
        assert!(err.to_string().contains("non-finite"), "{err}");
    }

    #[test]
    fn rejects_malformed_documents() {
        assert!(from_json(&Json::parse("{}").unwrap()).is_err());
        let wrong_format = r#"{"format": "deer-checkpoint-v0", "params": []}"#;
        assert!(from_json(&Json::parse(wrong_format).unwrap()).is_err());
        // declared/actual length mismatch
        let bad_len = r#"{"format": "deer-checkpoint-v1", "layers": 1, "num_params": 3,
                          "step": 0, "params": [1, 2], "adam_m": [0, 0], "adam_v": [0, 0]}"#;
        assert!(from_json(&Json::parse(bad_len).unwrap()).is_err());
        // moment length mismatch
        let bad_m = r#"{"format": "deer-checkpoint-v1", "layers": 1, "num_params": 2,
                        "step": 0, "params": [1, 2], "adam_m": [0], "adam_v": [0, 0]}"#;
        assert!(from_json(&Json::parse(bad_m).unwrap()).is_err());
        // missing file is a clean error
        assert!(load(&temp_path("never_written.json")).is_err());
    }

    /// Checkpoint weights can seed a bare cell (solver-fixture reuse).
    #[test]
    fn seeds_cell_fixture() {
        use crate::cells::Gru;
        use crate::util::rng::Rng;
        let mut rng = Rng::new(5);
        let cell: Gru<f32> = Gru::new(3, 2, &mut rng);
        let pc = cell.num_params();
        let mut params: Vec<f32> = (0..pc + 7).map(|i| i as f32 * 0.01).collect();
        params[0] = -1.25;
        let adam: Adam<f32> = Adam::new(params.len(), AdamConfig::default());
        let path = temp_path("fixture.json");
        save(&path, &params, &adam, 1, "constant").unwrap();
        let ck = load(&path).unwrap();
        let mut fresh: Gru<f32> = Gru::new(3, 2, &mut Rng::new(99));
        load_cell_params(&ck, &mut fresh).unwrap();
        assert_eq!(fresh.params(), &params[..pc]);
        std::fs::remove_file(&path).ok();
    }
}
