//! Native DEER training: data → per-layer fused batched solves → gradients
//! → Adam, entirely in-crate (no AOT artifacts, no Python at any point).
//!
//! This subsystem closes the loop the paper's §4.3 headline claim is about:
//! *training* a non-linear sequential model with the forward (and backward)
//! pass parallelised over the sequence length. It reproduces the EigenWorms
//! GRU classifier (and a two-body energy-regression variant) — including
//! multi-layer stacked-cell models — with the sequential-vs-DEER engine
//! choice reduced to one enum:
//!
//! ```text
//! data/loader ─ minibatch ─▶ forward (Seq | Deer | QuasiDeer | Hybrid
//!                                     | Elk | QuasiElk)
//!   layer 0: xs [B,T,m]   ─▶ ys₀ [B,T,n]   (ONE fused solve)
//!   layer 1: ys₀          ─▶ ys₁ [B,T,n]   (ONE fused solve)
//!   …          (each layer via coordinator::BatchExecutor, warm-started
//!               across epochs from its OWN per-layer trajectory cache)
//! model::Model ─ loss on ys_{L−1} ─▶ gs [B,T,n] + head grads
//!                                │
//! backward, top layer first (BPTT | deer_rnn_backward_batch_io):
//!   layer l: gs_l ─▶ dθ_l  AND  dxs_l = gs_{l−1}   (input-VJP chaining)
//!                                │
//! opt::Adam over flat [layer θ… | head θ] ─▶ Model::load_params round-trip
//! ```
//!
//! # Flat parameter layout
//!
//! Every trainable scalar lives in ONE flat `Vec`:
//!
//! ```text
//! [ cells[0] θ (its own params() order)
//! | …
//! | cells[L−1] θ
//! | W_out            (k·n_{L−1}, row-major)
//! | b_out            (k) ]
//! ```
//!
//! [`Model::write_params`] / [`Model::load_params`] are the only functions
//! that know this layout ([`Model::layer_param_range`] exposes each
//! layer's slice); the optimizer sees an opaque flat vector and each cell
//! round-trips through [`crate::cells::CellGrad::load_params`]. The
//! gradient vector produced by [`TrainLoop::grad_minibatch`] uses the same
//! layout, so `params[i]` and `grad[i]` always refer to the same scalar.
//! [`checkpoint`] persists the vector (plus Adam moments and the step
//! counter) as JSON — `deer train --save/--load`.
//!
//! # Seq-vs-Deer parity contract
//!
//! With equal seeds and configs, the `Seq` and `Deer` arms see identical
//! data order, loss algebra and optimizer state; they differ only in the
//! trajectory engine. `Deer` converges each layer's forward pass to the
//! paper-§3.5 tolerance and its backward pass is the exact eq.-7 dual scan
//! chained through exact input-VJPs, so per step the two gradients agree
//! to forward-tolerance level at ANY depth and the training curves track
//! each other (the `--exp train` bench and `tests/train_native.rs` hold
//! final accuracies within 2%). `QuasiDeer` additionally approximates the
//! backward λ-propagation (off-diagonal terms dropped on dense cells) and
//! is *not* covered by the exactness half of the contract — it trades
//! gradient bias for O(n) scans.

pub mod checkpoint;
pub mod model;
pub mod opt;
#[path = "loop.rs"]
pub mod train_loop;

pub use checkpoint::Checkpoint;
pub use model::{Model, Readout};
pub use opt::{Adam, AdamConfig, LrSchedule};
pub use train_loop::{
    twobody_task, worms_task, ForwardMode, MinibatchGrad, StepStats, Targets, TrainConfig,
    TrainData, TrainLoop, TrainStats,
};
