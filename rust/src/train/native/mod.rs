//! Native DEER training: data → fused batched solve → gradients → Adam,
//! entirely in-crate (no AOT artifacts, no Python at any point).
//!
//! This subsystem closes the loop the paper's §4.3 headline claim is about:
//! *training* a non-linear sequential model with the forward (and backward)
//! pass parallelised over the sequence length. It reproduces the EigenWorms
//! GRU classifier (and a two-body energy-regression variant) with the
//! sequential-vs-DEER engine choice reduced to one enum:
//!
//! ```text
//! data/loader ─ minibatch ─▶ forward (Seq | Deer | QuasiDeer) ─▶ ys [B,T,n]
//!                                │ (Deer modes: ONE fused solve per
//!                                │  minibatch via coordinator::BatchExecutor,
//!                                │  warm-started across epochs)
//! model::Model ─ loss ─▶ gs [B,T,n] + head grads
//!                                │
//! backward (BPTT | deer_rnn_backward_batch) ─▶ dθ_cell
//!                                │
//! opt::Adam over flat [cell θ | head θ] ─▶ Cell::load_params round-trip
//! ```
//!
//! # Flat parameter layout
//!
//! Every trainable scalar lives in ONE flat `Vec`:
//!
//! ```text
//! [ cell parameters (cell.num_params(), the cell's own params() order)
//! | W_out            (k·n, row-major)
//! | b_out            (k) ]
//! ```
//!
//! [`Model::write_params`] / [`Model::load_params`] are the only functions
//! that know this layout; the optimizer sees an opaque flat vector and the
//! cell round-trips through [`crate::cells::CellGrad::load_params`]. The
//! gradient vector produced by [`TrainLoop::grad_minibatch`] uses the same
//! layout, so `params[i]` and `grad[i]` always refer to the same scalar.
//!
//! # Seq-vs-Deer parity contract
//!
//! With equal seeds and configs, the `Seq` and `Deer` arms see identical
//! data order, loss algebra and optimizer state; they differ only in the
//! trajectory engine. `Deer` converges the forward pass to the paper-§3.5
//! tolerance and its backward pass is the exact eq.-7 dual scan, so per
//! step the two gradients agree to forward-tolerance level and the training
//! curves track each other (the `--exp train` bench and
//! `tests/train_native.rs` hold final accuracies within 2%). `QuasiDeer`
//! additionally approximates the backward λ-propagation (off-diagonal terms
//! dropped on dense cells) and is *not* covered by the exactness half of
//! the contract — it trades gradient bias for O(n) scans.

pub mod model;
pub mod opt;
#[path = "loop.rs"]
pub mod train_loop;

pub use model::{Model, Readout};
pub use opt::{Adam, AdamConfig};
pub use train_loop::{
    twobody_task, worms_task, ForwardMode, MinibatchGrad, StepStats, Targets, TrainConfig,
    TrainData, TrainLoop, TrainStats,
};
