//! # DEER — Parallelizing non-linear sequential models over the sequence length
//!
//! Production reproduction of Lim, Zhu, Selfridge & Kasim (ICLR 2024).
//!
//! The crate is organised as the Layer-3 (coordinator) half of a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * [`util`] — foundation: CLI parsing, JSON, RNG, timing, table rendering
//!   (the offline image has no clap/serde/criterion, so these are in-repo).
//! * [`linalg`] — small dense matrices, LU solves, matrix exponential.
//! * [`cells`] — non-linear recurrent cells (GRU / LSTM / LEM / Elman) with
//!   *analytic* state Jacobians and parameter VJPs.
//! * [`scan`] — sequential and multi-threaded parallel prefix scans over the
//!   affine elements `(A, b)` of eq. (10) in the paper, with O(n)
//!   structure-specialized kernels for diagonal Jacobians (quasi-DEER) and
//!   fused batched variants over the `[B, T, n]` layout.
//! * [`deer`] — the DEER algorithm itself: Newton fixed-point iteration for
//!   RNNs (eq. 3/5) with batched solves and per-sequence convergence
//!   masking, the single-pass backward gradient (eq. 7), the DEER-ODE
//!   solver (eq. 8–10) plus sequential / BPTT / RK45 baselines.
//! * [`simulator`] — accelerator cost model (work/depth → simulated V100 /
//!   A100 wall-clock); the testbed is a single CPU core, so paper-scale
//!   speedups are reproduced through this calibrated model while measured
//!   wall-clock is always reported alongside.
//! * [`coordinator`] — the systems layer: sweep scheduler, dynamic batcher
//!   + batched execution engine (one fused solve per flushed group),
//!   warm-start trajectory cache (App. B.2), convergence policy, memory
//!   accounting.
//! * [`runtime`] — PJRT runtime that loads AOT-lowered HLO-text artifacts
//!   produced by `python/compile/aot.py` and executes them on the hot path
//!   (Python never runs at request time).
//! * [`data`] — dataset substrates: two-body gravitational simulator,
//!   synthetic EigenWorms, sequential-CIFAR-like generator.
//! * [`train`] — training: the native in-crate trainer
//!   ([`train::native`]: model head + Adam + minibatch loop with the
//!   Seq/DEER/quasi-DEER engine switch, §4.3) and the artifact-driven
//!   loops (HNN / EigenWorms classifier via the `xla` runtime).
//! * [`telemetry`] — structured observability: hierarchical spans with a
//!   zero-cost-when-disabled sink, the enum-keyed metric registry
//!   (counters/gauges/histograms), Chrome trace-event export for Perfetto,
//!   and the per-bench run manifest.
//! * [`metrics`] — run recording and paper-table reporting.
//! * [`testkit`] — in-repo property-testing mini-framework.

pub mod util;
pub mod linalg;
pub mod cells;
pub mod scan;
pub mod deer;
pub mod simulator;
pub mod coordinator;
pub mod runtime;
pub mod data;
pub mod experiments;
pub mod train;
pub mod telemetry;
pub mod metrics;
pub mod testkit;

pub use cells::{Cell, CellGrad, Elman, Gru, IndRnn, JacobianStructure, Lem, Lstm};
pub use coordinator::BatchExecutor;
pub use deer::{
    deer_rnn, deer_rnn_batch, BatchDeerResult, BatchGradResult, DeerConfig, DeerResult,
    JacobianMode,
};
pub use train::native::{ForwardMode, Model, Readout, TrainConfig, TrainLoop};
pub use util::scalar::Scalar;
