//! In-repo property-testing mini-framework.
//!
//! proptest is not in the offline registry, so this provides the shape the
//! test suite needs: run a property over many random inputs, report the
//! failing seed/case, and rerun deterministically. The Python side uses
//! hypothesis (which IS installed) for the kernel sweeps.

use crate::util::rng::Rng;

/// Outcome of a property check.
pub type PropResult = Result<(), String>;

/// Run `prop` over `iters` random inputs produced by `gen`.
///
/// On failure, panics with the iteration index, seed and the failure
/// message so the case can be replayed (`forall_seeded` with that seed).
pub fn forall<T, G, P>(iters: usize, seed: u64, mut gen: G, prop: P)
where
    G: FnMut(&mut Rng) -> T,
    P: Fn(&T) -> PropResult,
    T: std::fmt::Debug,
{
    let mut rng = Rng::new(seed);
    for i in 0..iters {
        let case_seed = rng.next_u64();
        let mut case_rng = Rng::new(case_seed);
        let case = gen(&mut case_rng);
        if let Err(msg) = prop(&case) {
            panic!(
                "property failed at iteration {i} (case_seed={case_seed:#x}):\n  {msg}\n  case: {case:?}"
            );
        }
    }
}

/// Replay a single case by seed.
pub fn forall_seeded<T, G, P>(case_seed: u64, mut gen: G, prop: P)
where
    G: FnMut(&mut Rng) -> T,
    P: Fn(&T) -> PropResult,
    T: std::fmt::Debug,
{
    let mut case_rng = Rng::new(case_seed);
    let case = gen(&mut case_rng);
    if let Err(msg) = prop(&case) {
        panic!("property failed (case_seed={case_seed:#x}): {msg}\n  case: {case:?}");
    }
}

/// Helper: approximate slice equality with context.
pub fn close(a: &[f64], b: &[f64], tol: f64) -> PropResult {
    if a.len() != b.len() {
        return Err(format!("length mismatch {} vs {}", a.len(), b.len()));
    }
    for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        if (x - y).abs() > tol {
            return Err(format!("element {i}: {x} vs {y} (tol {tol})"));
        }
    }
    Ok(())
}

/// Committed solver fixtures (the ROADMAP's ill-conditioned-fixture item):
/// trained/crafted weight sets that stress the Newton solve in ways random
/// inits don't, loaded through the real [`crate::train::native::checkpoint`]
/// API so the fixtures double as format regression tests.
pub mod fixtures {
    use crate::cells::Gru;
    use crate::train::native::checkpoint::{self, Checkpoint};
    use crate::util::json::Json;
    use crate::util::rng::Rng;

    /// `deer-checkpoint-v1` document of the diverging-GRU fixture: a GRU
    /// whose state Jacobian is exactly diagonal by construction (recurrent
    /// reset/update weights zero, candidate weights `W_hn = 3·I`, constant
    /// reset gate r = ½ and a nearly-closed update gate z = σ(−4) ≈ 0.018
    /// from `b_iz = −4`) with per-step diagonal entries
    /// `J = (1−z)·(1−ñ²)·3/2 + z`. From the cold start `y = 0` the entries
    /// average ≈ 1.06 — individually mild, but the undamped INVLIN prefix
    /// products compound that drift over the horizon and overflow f32 near
    /// step ~3.3k, so plain DEER *must* freeze with
    /// [`crate::deer::DivergenceReason::NonFinite`] at any T ≥ 16k (it still
    /// converges at T ≤ 2k). The `b_in = ±5/8` biases hold every coordinate
    /// in a single tanh basin (the bistable |c| window at drive 3/2 is
    /// ±0.04, far below the bias), so the adaptively damped ELK solve walks
    /// into the attractor — where `J ≈ 0.15` contracts — and converges on
    /// the very same weights in a handful of sweeps.
    /// `tests/divergence_fixture.rs` pins both halves.
    pub const DIVERGING_GRU_JSON: &str = include_str!("fixtures/diverging_gru_ckpt.json");
    /// (hidden, input) dims the fixture checkpoint was written for.
    pub const DIVERGING_GRU_DIMS: (usize, usize) = (6, 3);
    /// Seed of the committed input stream that accompanies the weights
    /// ([`diverging_gru_inputs`]).
    pub const DIVERGING_GRU_INPUT_SEED: u64 = 22;

    /// Parse the committed fixture checkpoint.
    pub fn diverging_gru_checkpoint() -> Checkpoint {
        let doc = Json::parse(DIVERGING_GRU_JSON).expect("committed fixture parses as JSON");
        checkpoint::from_json(&doc).expect("committed fixture is a valid checkpoint")
    }

    /// Build the fixture cell via the public checkpoint-seeding API.
    pub fn diverging_gru() -> Gru<f32> {
        let (n, m) = DIVERGING_GRU_DIMS;
        let mut cell: Gru<f32> = Gru::new(n, m, &mut Rng::new(0));
        checkpoint::load_cell_params(&diverging_gru_checkpoint(), &mut cell)
            .expect("fixture params fit the cell");
        cell
    }

    /// The committed input stream (first `t_len` steps of it).
    pub fn diverging_gru_inputs(t_len: usize) -> Vec<f32> {
        let (_, m) = DIVERGING_GRU_DIMS;
        let mut rng = Rng::new(DIVERGING_GRU_INPUT_SEED);
        let mut xs = vec![0.0f32; t_len * m];
        rng.fill_normal(&mut xs, 1.0);
        xs
    }

    /// The closed-form recipe behind the committed JSON — every value is an
    /// exact binary fraction so the JSON round trip is bitwise. This is the
    /// regeneration source of truth: `diverging_gru_fixture_matches_recipe`
    /// pins the committed file against it, and the `#[ignore]`d
    /// `regenerate_diverging_gru_fixture` rewrites the file from it.
    pub fn diverging_gru_recipe_params() -> Vec<f32> {
        let (n, m) = DIVERGING_GRU_DIMS;
        let mut p = vec![0.0f32; 3 * n * m + 3 * n * n + 6 * n];
        // W_in: a fixed residue pattern over exact 32nds in [-5/32, 5/32] —
        // small couplings keep the cold-anchor tanh arguments near the bias.
        for i in 0..n * m {
            p[2 * n * m + i] = (((i * 5 + 3) % 11) as f32 - 5.0) / 32.0;
        }
        // W_hn = 3·I — with r = ½ a candidate drive of 3/2: mildly
        // expansive at the cold anchor, monostable once biased.
        let w_hn = 3 * n * m + 2 * n * n;
        for i in 0..n {
            p[w_hn + i * n + i] = 3.0;
        }
        // b_iz = −4: update gate z = σ(−4) ≈ 0.018, almost no state leak,
        // which is what pushes the cold-anchor Jacobian mean above 1.
        let b_iz = 3 * n * m + 3 * n * n + n;
        for i in 0..n {
            p[b_iz + i] = -4.0;
        }
        // b_in = ±5/8 alternating: pins each coordinate to one tanh basin
        // so the damped solve never has to cross a basin boundary.
        let b_in = 3 * n * m + 3 * n * n + 2 * n;
        for i in 0..n {
            p[b_in + i] = if i % 2 == 0 { 0.625 } else { -0.625 };
        }
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        forall(
            50,
            1,
            |rng| rng.uniform_in(-10.0, 10.0),
            |x| {
                if x * x >= 0.0 {
                    Ok(())
                } else {
                    Err("squares are nonnegative".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        forall(
            50,
            2,
            |rng| rng.uniform_in(0.0, 1.0),
            |x| {
                if *x < 0.5 {
                    Ok(())
                } else {
                    Err(format!("{x} >= 0.5"))
                }
            },
        );
    }

    #[test]
    fn close_reports_index() {
        let e = close(&[1.0, 2.0], &[1.0, 3.0], 0.1).unwrap_err();
        assert!(e.contains("element 1"));
    }

    /// The committed fixture JSON is byte-for-byte the recipe: params match
    /// exactly (all values are binary fractions, so no tolerance), the
    /// optimizer state is pristine and the declared shape is the 6×3 GRU.
    #[test]
    fn diverging_gru_fixture_matches_recipe() {
        let ck = fixtures::diverging_gru_checkpoint();
        assert_eq!(ck.params, fixtures::diverging_gru_recipe_params());
        assert_eq!(ck.step, 0);
        assert_eq!(ck.layers, 1);
        assert!(ck.adam_m.iter().chain(ck.adam_v.iter()).all(|&v| v == 0.0));
        let (n, m) = fixtures::DIVERGING_GRU_DIMS;
        assert_eq!(ck.params.len(), 3 * n * m + 3 * n * n + 6 * n);
        // and the cell loader accepts it
        use crate::cells::CellGrad;
        assert_eq!(fixtures::diverging_gru().params(), &ck.params[..]);
    }

    /// Regenerate the committed fixture from the recipe (run manually with
    /// `cargo test -- --ignored regenerate_diverging_gru_fixture` after
    /// changing [`fixtures::diverging_gru_recipe_params`]; whitespace may
    /// differ from the checked-in file, values cannot).
    #[test]
    #[ignore]
    fn regenerate_diverging_gru_fixture() {
        use crate::train::native::opt::{Adam, AdamConfig};
        let params = fixtures::diverging_gru_recipe_params();
        let adam: Adam<f32> = Adam::new(params.len(), AdamConfig::default());
        let doc = crate::train::native::checkpoint::to_json(&params, &adam, 1, "constant");
        let path = concat!(
            env!("CARGO_MANIFEST_DIR"),
            "/src/testkit/fixtures/diverging_gru_ckpt.json"
        );
        std::fs::write(path, doc.to_string()).unwrap();
    }
}
