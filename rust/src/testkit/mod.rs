//! In-repo property-testing mini-framework.
//!
//! proptest is not in the offline registry, so this provides the shape the
//! test suite needs: run a property over many random inputs, report the
//! failing seed/case, and rerun deterministically. The Python side uses
//! hypothesis (which IS installed) for the kernel sweeps.

use crate::util::rng::Rng;

/// Outcome of a property check.
pub type PropResult = Result<(), String>;

/// Run `prop` over `iters` random inputs produced by `gen`.
///
/// On failure, panics with the iteration index, seed and the failure
/// message so the case can be replayed (`forall_seeded` with that seed).
pub fn forall<T, G, P>(iters: usize, seed: u64, mut gen: G, prop: P)
where
    G: FnMut(&mut Rng) -> T,
    P: Fn(&T) -> PropResult,
    T: std::fmt::Debug,
{
    let mut rng = Rng::new(seed);
    for i in 0..iters {
        let case_seed = rng.next_u64();
        let mut case_rng = Rng::new(case_seed);
        let case = gen(&mut case_rng);
        if let Err(msg) = prop(&case) {
            panic!(
                "property failed at iteration {i} (case_seed={case_seed:#x}):\n  {msg}\n  case: {case:?}"
            );
        }
    }
}

/// Replay a single case by seed.
pub fn forall_seeded<T, G, P>(case_seed: u64, mut gen: G, prop: P)
where
    G: FnMut(&mut Rng) -> T,
    P: Fn(&T) -> PropResult,
    T: std::fmt::Debug,
{
    let mut case_rng = Rng::new(case_seed);
    let case = gen(&mut case_rng);
    if let Err(msg) = prop(&case) {
        panic!("property failed (case_seed={case_seed:#x}): {msg}\n  case: {case:?}");
    }
}

/// Helper: approximate slice equality with context.
pub fn close(a: &[f64], b: &[f64], tol: f64) -> PropResult {
    if a.len() != b.len() {
        return Err(format!("length mismatch {} vs {}", a.len(), b.len()));
    }
    for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        if (x - y).abs() > tol {
            return Err(format!("element {i}: {x} vs {y} (tol {tol})"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        forall(
            50,
            1,
            |rng| rng.uniform_in(-10.0, 10.0),
            |x| {
                if x * x >= 0.0 {
                    Ok(())
                } else {
                    Err("squares are nonnegative".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        forall(
            50,
            2,
            |rng| rng.uniform_in(0.0, 1.0),
            |x| {
                if *x < 0.5 {
                    Ok(())
                } else {
                    Err(format!("{x} >= 0.5"))
                }
            },
        );
    }

    #[test]
    fn close_reports_index() {
        let e = close(&[1.0, 2.0], &[1.0, 3.0], 0.1).unwrap_err();
        assert!(e.contains("element 1"));
    }
}
