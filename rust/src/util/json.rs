//! Minimal JSON parser / writer.
//!
//! Used for the artifact manifest exchanged with `python/compile/aot.py` and
//! for experiment result files. Supports the full JSON grammar except for
//! `\u` surrogate pairs (the manifest is ASCII). serde is not available in
//! the offline vendored registry.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|v| v as usize)
    }
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|v| v as i64)
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Serialize to a compact JSON string.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{}", n);
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(format!("trailing data at byte {}", p.pos));
        }
        Ok(v)
    }
}

/// Convenience constructors.
pub fn obj(fields: Vec<(&str, Json)>) -> Json {
    Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}
pub fn arr(items: Vec<Json>) -> Json {
    Json::Arr(items)
}
pub fn num(v: f64) -> Json {
    Json::Num(v)
}
pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }
    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }
    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }
    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.pos))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|c| c as char), self.pos)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| format!("bad number {:?}: {e}", text))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err("unterminated string".into()),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b't') => out.push('\t'),
                    Some(b'r') => out.push('\r'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or("eof in \\u")? as char;
                            code = code * 16 + c.to_digit(16).ok_or("bad hex in \\u")?;
                        }
                        out.push(char::from_u32(code).ok_or("bad codepoint")?);
                    }
                    other => return Err(format!("bad escape {:?}", other)),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // Re-decode UTF-8 multibyte sequence.
                    let len = if c >= 0xF0 {
                        4
                    } else if c >= 0xE0 {
                        3
                    } else {
                        2
                    };
                    let start = self.pos - 1;
                    self.pos = start + len;
                    let chunk = self.bytes.get(start..start + len).ok_or("bad utf8")?;
                    out.push_str(std::str::from_utf8(chunk).map_err(|e| e.to_string())?);
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                other => return Err(format!("expected , or ] got {:?}", other.map(|c| c as char))),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                other => return Err(format!("expected , or }} got {:?}", other.map(|c| c as char))),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": {"c": "hi\nthere", "d": null}, "e": true}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn field_access() {
        let v = Json::parse(r#"{"shape": [16, 24], "dtype": "f32"}"#).unwrap();
        assert_eq!(v.get("dtype").unwrap().as_str(), Some("f32"));
        let shape: Vec<usize> = v
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|x| x.as_usize().unwrap())
            .collect();
        assert_eq!(shape, vec![16, 24]);
    }

    #[test]
    fn rejects_trailing() {
        assert!(Json::parse("{} x").is_err());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{,}").is_err());
        assert!(Json::parse("[1,").is_err());
        assert!(Json::parse("\"abc").is_err());
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str(), Some("Aé"));
    }

    #[test]
    fn utf8_passthrough() {
        let v = Json::parse("\"héllo → world\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo → world"));
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(Default::default()));
    }

    #[test]
    fn integers_stay_integral() {
        let v = obj(vec![("n", num(17984.0))]);
        assert_eq!(v.to_string(), r#"{"n":17984}"#);
    }
}
