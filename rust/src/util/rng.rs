//! Deterministic pseudo-random number generation.
//!
//! SplitMix64 core (Steele et al., 2014) with helpers for uniform / normal /
//! categorical sampling. Deterministic seeding keeps every experiment in
//! EXPERIMENTS.md bit-reproducible; the vendored registry has no `rand`, so
//! this is the crate-wide RNG.

/// SplitMix64 PRNG. Small state, passes BigCrush when used as a stream.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Create a generator from a seed.
    pub fn new(seed: u64) -> Self {
        Rng {
            state: seed.wrapping_add(0x9E3779B97F4A7C15),
        }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        // 53 high bits -> [0,1) double.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let mut u1 = self.uniform();
        if u1 <= f64::MIN_POSITIVE {
            u1 = f64::MIN_POSITIVE;
        }
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free for our purposes (bias < 2^-53).
        (self.uniform() * n as f64) as usize % n
    }

    /// Fill a slice with i.i.d. N(0, scale^2) samples.
    pub fn fill_normal<S: crate::util::scalar::Scalar>(&mut self, out: &mut [S], scale: f64) {
        for v in out.iter_mut() {
            *v = S::from_f64c(self.normal() * scale);
        }
    }

    /// Fill a slice with i.i.d. U(lo, hi) samples.
    pub fn fill_uniform<S: crate::util::scalar::Scalar>(&mut self, out: &mut [S], lo: f64, hi: f64) {
        for v in out.iter_mut() {
            *v = S::from_f64c(self.uniform_in(lo, hi));
        }
    }

    /// A fresh generator split off this one (independent stream).
    pub fn split(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }

    /// Random permutation of 0..n (Fisher–Yates).
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        for i in (1..n).rev() {
            let j = self.below(i + 1);
            p.swap(i, j);
        }
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_range() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(7);
        let n = 200_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn permutation_is_permutation() {
        let mut r = Rng::new(3);
        let p = r.permutation(100);
        let mut seen = vec![false; 100];
        for &i in &p {
            assert!(!seen[i]);
            seen[i] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn split_streams_differ() {
        let mut a = Rng::new(5);
        let mut b = a.split();
        let xs: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(xs, ys);
    }
}
