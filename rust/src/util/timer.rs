//! Wall-clock benchmarking helpers.
//!
//! criterion is unavailable offline; this module provides the statistical
//! core the benchmark harness needs: warmup, repeated measurement, and
//! mean / std / min reporting.

use std::time::{Duration, Instant};

/// Summary statistics over repeated timings.
#[derive(Debug, Clone)]
pub struct Timing {
    pub samples: Vec<f64>, // seconds
}

impl Timing {
    pub fn mean(&self) -> f64 {
        self.samples.iter().sum::<f64>() / self.samples.len().max(1) as f64
    }
    pub fn std(&self) -> f64 {
        if self.samples.len() < 2 {
            return 0.0;
        }
        let m = self.mean();
        let v = self.samples.iter().map(|x| (x - m) * (x - m)).sum::<f64>()
            / (self.samples.len() - 1) as f64;
        v.sqrt()
    }
    pub fn min(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::INFINITY, f64::min)
    }
    pub fn median(&self) -> f64 {
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        if s.is_empty() {
            return 0.0;
        }
        let mid = s.len() / 2;
        if s.len() % 2 == 0 {
            (s[mid - 1] + s[mid]) / 2.0
        } else {
            s[mid]
        }
    }
}

/// Benchmark a closure: `warmup` unmeasured runs then `reps` measured runs.
pub fn bench<F: FnMut()>(warmup: usize, reps: usize, mut f: F) -> Timing {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    Timing { samples }
}

/// Benchmark with an adaptive repetition count: keep measuring until either
/// `max_reps` samples or `budget` wall-clock is spent (at least `min_reps`).
pub fn bench_budget<F: FnMut()>(min_reps: usize, max_reps: usize, budget: Duration, mut f: F) -> Timing {
    // one warmup
    f();
    let start = Instant::now();
    let mut samples = Vec::new();
    while samples.len() < max_reps && (samples.len() < min_reps || start.elapsed() < budget) {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    Timing { samples }
}

/// Format seconds human-readably.
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.1} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{:.2} s", s)
    }
}

/// Simple phase stopwatch for profiling (Table 5 phases: FUNCEVAL — which
/// since the batched refactor includes the fused GTMULT rhs build — and
/// INVLIN; the damped path adds RESIDUAL, the backward pass JACOBIAN /
/// DUAL_SCAN / PARAM_VJP, the ODE path DISCRETIZE). Keys are the shared
/// [`crate::telemetry::Phase`] enum — free-string labels (and their drift
/// between forward and backward) are gone, and [`PhaseProfile::record`]
/// doubles as the telemetry span emitter for every phase site.
#[derive(Debug, Default, Clone)]
pub struct PhaseProfile {
    entries: Vec<(Phase, f64)>,
}

use crate::telemetry::Phase;

impl PhaseProfile {
    pub fn new() -> Self {
        Self::default()
    }
    /// Time a closure under the given phase, accumulating. When the
    /// telemetry sink is enabled this also emits a span named after the
    /// phase — one instrumentation point covers every solver phase.
    pub fn record<T>(&mut self, phase: Phase, f: impl FnOnce() -> T) -> T {
        let span = crate::telemetry::span(phase.label());
        let t0 = Instant::now();
        let out = f();
        let secs = t0.elapsed().as_secs_f64();
        drop(span);
        self.add(phase, secs);
        out
    }
    /// Add raw seconds to a phase.
    pub fn add(&mut self, phase: Phase, secs: f64) {
        if let Some(e) = self.entries.iter_mut().find(|(p, _)| *p == phase) {
            e.1 += secs;
        } else {
            self.entries.push((phase, secs));
        }
    }
    pub fn get(&self, phase: Phase) -> f64 {
        self.entries
            .iter()
            .find(|(p, _)| *p == phase)
            .map(|(_, s)| *s)
            .unwrap_or(0.0)
    }
    pub fn entries(&self) -> &[(Phase, f64)] {
        &self.entries
    }
    pub fn total(&self) -> f64 {
        self.entries.iter().map(|(_, s)| s).sum()
    }
    pub fn merge(&mut self, other: &PhaseProfile) {
        for (p, s) in &other.entries {
            self.add(*p, *s);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_sane() {
        let t = Timing {
            samples: vec![1.0, 2.0, 3.0],
        };
        assert!((t.mean() - 2.0).abs() < 1e-12);
        assert!((t.std() - 1.0).abs() < 1e-12);
        assert_eq!(t.min(), 1.0);
        assert_eq!(t.median(), 2.0);
    }

    #[test]
    fn bench_runs() {
        let mut count = 0;
        let t = bench(2, 5, || count += 1);
        assert_eq!(count, 7);
        assert_eq!(t.samples.len(), 5);
    }

    #[test]
    fn phase_profile_accumulates() {
        let mut p = PhaseProfile::new();
        p.add(Phase::FuncEval, 0.5);
        p.add(Phase::FuncEval, 0.25);
        p.add(Phase::Invlin, 1.0);
        assert!((p.get(Phase::FuncEval) - 0.75).abs() < 1e-12);
        assert!((p.total() - 1.75).abs() < 1e-12);
    }

    #[test]
    fn fmt_units() {
        assert!(fmt_secs(2e-9).ends_with("ns"));
        assert!(fmt_secs(2e-6).ends_with("µs"));
        assert!(fmt_secs(2e-3).ends_with("ms"));
        assert!(fmt_secs(2.0).ends_with(" s"));
    }
}
