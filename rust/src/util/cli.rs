//! Minimal command-line argument parsing (clap is unavailable offline).
//!
//! Supports `program SUBCOMMAND --flag value --switch positional...` with
//! typed accessors and helpful error messages.

use std::collections::BTreeMap;

/// Parsed command-line arguments.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    flags: BTreeMap<String, String>,
    switches: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding `argv[0]`).
    pub fn parse_from<I: IntoIterator<Item = String>>(items: I) -> Result<Args, String> {
        let mut out = Args::default();
        let mut it = items.into_iter().peekable();
        // First non-flag token is the subcommand.
        if let Some(first) = it.peek() {
            if !first.starts_with('-') {
                out.subcommand = it.next();
            }
        }
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if name.is_empty() {
                    // `--` terminator: remainder is positional.
                    out.positional.extend(it.by_ref());
                    break;
                }
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else {
                    // Value if next token exists and is not a flag.
                    match it.peek() {
                        Some(next) if !next.starts_with("--") => {
                            let v = it.next().unwrap();
                            out.flags.insert(name.to_string(), v);
                        }
                        _ => out.switches.push(name.to_string()),
                    }
                }
            } else {
                out.positional.push(tok);
            }
        }
        Ok(out)
    }

    /// Parse from the process environment.
    pub fn from_env() -> Result<Args, String> {
        Args::parse_from(std::env::args().skip(1))
    }

    /// Boolean switch (`--verbose`).
    pub fn switch(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name) || self.flags.get(name).map(|v| v == "true").unwrap_or(false)
    }

    /// String flag with default.
    pub fn get<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.flags.get(name).map(|s| s.as_str()).unwrap_or(default)
    }

    /// Optional string flag.
    pub fn opt(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    /// Required flag.
    pub fn require(&self, name: &str) -> Result<&str, String> {
        self.opt(name).ok_or_else(|| format!("missing required flag --{name}"))
    }

    /// Typed flag with default.
    pub fn get_parse<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T, String>
    where
        T::Err: std::fmt::Display,
    {
        match self.flags.get(name) {
            None => Ok(default),
            Some(v) => v.parse::<T>().map_err(|e| format!("--{name}={v}: {e}")),
        }
    }

    /// Comma-separated list flag, e.g. `--dims 1,2,4`.
    pub fn get_list<T: std::str::FromStr>(&self, name: &str, default: &[T]) -> Result<Vec<T>, String>
    where
        T::Err: std::fmt::Display,
        T: Clone,
    {
        match self.flags.get(name) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .filter(|s| !s.is_empty())
                .map(|s| s.trim().parse::<T>().map_err(|e| format!("--{name}: {s}: {e}")))
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse_from(s.split_whitespace().map(|t| t.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("bench --exp fig2 --dims 1,2,4 --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("bench"));
        assert_eq!(a.get("exp", ""), "fig2");
        assert!(a.switch("verbose"));
        assert_eq!(a.get_list::<usize>("dims", &[]).unwrap(), vec![1, 2, 4]);
    }

    #[test]
    fn equals_form() {
        let a = parse("train --steps=300 --lr=0.001");
        assert_eq!(a.get_parse::<usize>("steps", 0).unwrap(), 300);
        assert!((a.get_parse::<f64>("lr", 0.0).unwrap() - 0.001).abs() < 1e-12);
    }

    #[test]
    fn defaults_and_required() {
        let a = parse("run");
        assert_eq!(a.get("mode", "deer"), "deer");
        assert!(a.require("missing").is_err());
        assert_eq!(a.get_parse::<usize>("n", 8).unwrap(), 8);
    }

    #[test]
    fn positional_after_double_dash() {
        let a = parse("exec --flag v -- a b");
        assert_eq!(a.positional, vec!["a", "b"]);
    }

    #[test]
    fn bad_parse_is_error() {
        let a = parse("x --n abc");
        assert!(a.get_parse::<usize>("n", 0).is_err());
    }
}
