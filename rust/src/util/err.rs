//! Minimal error type + helpers (anyhow is not in the offline registry).
//!
//! Provides the narrow slice of the `anyhow` API the crate uses: a
//! string-backed [`Error`], a defaulted [`Result`] alias, the
//! [`Context`] extension trait, and the [`anyhow!`](crate::anyhow) /
//! [`bail!`](crate::bail) macros.

use std::fmt;

/// A string-backed error with optional context chain.
pub struct Error(String);

impl Error {
    pub fn msg(m: impl fmt::Display) -> Error {
        Error(m.to_string())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

impl From<String> for Error {
    fn from(s: String) -> Error {
        Error(s)
    }
}

impl From<&str> for Error {
    fn from(s: &str) -> Error {
        Error(s.to_string())
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Error {
        Error(e.to_string())
    }
}

impl From<std::fmt::Error> for Error {
    fn from(e: std::fmt::Error) -> Error {
        Error(e.to_string())
    }
}

/// Crate-wide result alias (mirrors `anyhow::Result`).
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `.context(...)` / `.with_context(...)` on results and options.
pub trait Context<T> {
    fn context(self, msg: impl fmt::Display) -> Result<T>;
    fn with_context(self, f: impl FnOnce() -> String) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context(self, msg: impl fmt::Display) -> Result<T> {
        self.map_err(|e| Error(format!("{msg}: {e}")))
    }
    fn with_context(self, f: impl FnOnce() -> String) -> Result<T> {
        self.map_err(|e| Error(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context(self, msg: impl fmt::Display) -> Result<T> {
        self.ok_or_else(|| Error(msg.to_string()))
    }
    fn with_context(self, f: impl FnOnce() -> String) -> Result<T> {
        self.ok_or_else(|| Error(f()))
    }
}

/// Construct an [`Error`] from a format string (mirrors `anyhow::anyhow!`).
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::util::err::Error::msg(format!($($arg)*))
    };
}

/// Early-return with a formatted [`Error`] (mirrors `anyhow::bail!`).
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*).into())
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        Err(Error::msg("boom"))
    }

    #[test]
    fn display_and_context() {
        let e = fails().context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: boom");
        let v: Option<u32> = None;
        let e = v.with_context(|| "missing".to_string()).unwrap_err();
        assert_eq!(e.to_string(), "missing");
    }

    #[test]
    fn conversions() {
        fn io_path() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/a/file")?;
            Ok(s)
        }
        assert!(io_path().is_err());
        let e: Error = "plain".into();
        assert_eq!(format!("{e:?}"), "plain");
    }

    #[test]
    fn macros() {
        fn f(x: i32) -> Result<i32> {
            if x < 0 {
                bail!("negative: {x}");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(f(-1).unwrap_err().to_string(), "negative: -1");
        let e = anyhow!("code {}", 7);
        assert_eq!(e.to_string(), "code 7");
    }
}
