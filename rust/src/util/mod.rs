//! Foundation utilities.
//!
//! The build image is fully offline and its vendored crate set does not
//! include clap / serde / criterion / rand / anyhow / num-traits, so this
//! module provides the small subset of their functionality the rest of the
//! crate needs.

pub mod cli;
pub mod err;
pub mod json;
pub mod rng;
pub mod scalar;
pub mod table;
pub mod timer;
