//! Floating-point scalar abstraction.
//!
//! The paper evaluates DEER under both f32 and f64 (Fig. 6: iteration count
//! vs. tolerance per precision), so the whole engine is generic over
//! [`Scalar`]. Default convergence tolerances follow §3.5 of the paper:
//! `1e-4` for single precision and `1e-7` for double precision.
//!
//! The trait is self-contained (no `num-traits`: the offline registry does
//! not carry it); it exposes exactly the float surface the engine uses.
//! Inherent `f32`/`f64` methods shadow the trait methods at concrete call
//! sites, so only generic code resolves through the trait.

use std::fmt::{Debug, Display};
use std::iter::Sum;
use std::ops::{Add, AddAssign, Div, DivAssign, Mul, MulAssign, Neg, Sub, SubAssign};

/// Floating point scalar usable throughout the DEER engine.
pub trait Scalar:
    Copy
    + Clone
    + PartialOrd
    + PartialEq
    + Add<Output = Self>
    + Sub<Output = Self>
    + Mul<Output = Self>
    + Div<Output = Self>
    + Neg<Output = Self>
    + AddAssign
    + SubAssign
    + MulAssign
    + DivAssign
    + Sum
    + Debug
    + Display
    + Send
    + Sync
    + 'static
{
    /// Human-readable dtype name ("f32" / "f64").
    const NAME: &'static str;

    fn zero() -> Self;
    fn one() -> Self;

    fn abs(self) -> Self;
    fn sqrt(self) -> Self;
    fn exp(self) -> Self;
    fn ln(self) -> Self;
    fn log2(self) -> Self;
    fn log10(self) -> Self;
    fn sin(self) -> Self;
    fn cos(self) -> Self;
    fn tanh(self) -> Self;
    fn powi(self, n: i32) -> Self;
    fn powf(self, p: Self) -> Self;
    fn floor(self) -> Self;
    fn ceil(self) -> Self;
    fn round(self) -> Self;
    fn max(self, other: Self) -> Self;
    fn min(self, other: Self) -> Self;
    fn is_finite(self) -> bool;
    fn is_nan(self) -> bool;

    /// Paper §3.5 default convergence tolerance for this precision.
    fn default_tol() -> Self;

    /// Machine epsilon.
    fn eps() -> Self;

    /// Lossless-ish conversion from f64 (used for constants).
    fn from_f64c(v: f64) -> Self;

    /// Conversion to f64 for reporting.
    fn to_f64c(self) -> f64;
}

macro_rules! impl_scalar {
    ($t:ty, $name:literal, $tol:expr) => {
        impl Scalar for $t {
            const NAME: &'static str = $name;

            fn zero() -> Self {
                0.0
            }
            fn one() -> Self {
                1.0
            }
            fn abs(self) -> Self {
                <$t>::abs(self)
            }
            fn sqrt(self) -> Self {
                <$t>::sqrt(self)
            }
            fn exp(self) -> Self {
                <$t>::exp(self)
            }
            fn ln(self) -> Self {
                <$t>::ln(self)
            }
            fn log2(self) -> Self {
                <$t>::log2(self)
            }
            fn log10(self) -> Self {
                <$t>::log10(self)
            }
            fn sin(self) -> Self {
                <$t>::sin(self)
            }
            fn cos(self) -> Self {
                <$t>::cos(self)
            }
            fn tanh(self) -> Self {
                <$t>::tanh(self)
            }
            fn powi(self, n: i32) -> Self {
                <$t>::powi(self, n)
            }
            fn powf(self, p: Self) -> Self {
                <$t>::powf(self, p)
            }
            fn floor(self) -> Self {
                <$t>::floor(self)
            }
            fn ceil(self) -> Self {
                <$t>::ceil(self)
            }
            fn round(self) -> Self {
                <$t>::round(self)
            }
            fn max(self, other: Self) -> Self {
                <$t>::max(self, other)
            }
            fn min(self, other: Self) -> Self {
                <$t>::min(self, other)
            }
            fn is_finite(self) -> bool {
                <$t>::is_finite(self)
            }
            fn is_nan(self) -> bool {
                <$t>::is_nan(self)
            }
            fn default_tol() -> Self {
                $tol
            }
            fn eps() -> Self {
                <$t>::EPSILON
            }
            fn from_f64c(v: f64) -> Self {
                v as $t
            }
            fn to_f64c(self) -> f64 {
                self as f64
            }
        }
    };
}

impl_scalar!(f32, "f32", 1e-4);
impl_scalar!(f64, "f64", 1e-7);

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tolerances_match_paper() {
        assert_eq!(<f32 as Scalar>::default_tol(), 1e-4f32);
        assert_eq!(<f64 as Scalar>::default_tol(), 1e-7f64);
    }

    #[test]
    fn names() {
        assert_eq!(<f32 as Scalar>::NAME, "f32");
        assert_eq!(<f64 as Scalar>::NAME, "f64");
    }

    #[test]
    fn f64_roundtrip() {
        let x = <f64 as Scalar>::from_f64c(0.125);
        assert_eq!(x, 0.125);
        assert_eq!(x.to_f64c(), 0.125);
    }

    /// The generic surface must agree with the inherent float methods.
    #[test]
    fn generic_methods_match_inherent() {
        fn probe<S: Scalar>(v: S) -> (S, S, S, bool) {
            (v.abs(), v.exp(), v.tanh(), v.is_finite())
        }
        let (a, e, t, fin) = probe(-0.5f64);
        assert_eq!(a, 0.5);
        assert_eq!(e, (-0.5f64).exp());
        assert_eq!(t, (-0.5f64).tanh());
        assert!(fin);
        assert_eq!(<f64 as Scalar>::zero() + <f64 as Scalar>::one(), 1.0);
    }
}
