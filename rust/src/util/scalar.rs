//! Floating-point scalar abstraction.
//!
//! The paper evaluates DEER under both f32 and f64 (Fig. 6: iteration count
//! vs. tolerance per precision), so the whole engine is generic over
//! [`Scalar`]. Default convergence tolerances follow §3.5 of the paper:
//! `1e-4` for single precision and `1e-7` for double precision.

use num_traits::Float;

/// Floating point scalar usable throughout the DEER engine.
pub trait Scalar:
    Float
    + num_traits::NumAssign
    + num_traits::FromPrimitive
    + std::iter::Sum
    + std::fmt::Debug
    + std::fmt::Display
    + Send
    + Sync
    + 'static
{
    /// Human-readable dtype name ("f32" / "f64").
    const NAME: &'static str;

    /// Paper §3.5 default convergence tolerance for this precision.
    fn default_tol() -> Self;

    /// Machine epsilon.
    fn eps() -> Self;

    /// Lossless-ish conversion from f64 (used for constants).
    fn from_f64c(v: f64) -> Self {
        num_traits::FromPrimitive::from_f64(v).expect("f64 conversion")
    }

    /// Conversion to f64 for reporting.
    fn to_f64c(self) -> f64;
}

impl Scalar for f32 {
    const NAME: &'static str = "f32";
    fn default_tol() -> Self {
        1e-4
    }
    fn eps() -> Self {
        f32::EPSILON
    }
    fn to_f64c(self) -> f64 {
        self as f64
    }
}

impl Scalar for f64 {
    const NAME: &'static str = "f64";
    fn default_tol() -> Self {
        1e-7
    }
    fn eps() -> Self {
        f64::EPSILON
    }
    fn to_f64c(self) -> f64 {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tolerances_match_paper() {
        assert_eq!(<f32 as Scalar>::default_tol(), 1e-4f32);
        assert_eq!(<f64 as Scalar>::default_tol(), 1e-7f64);
    }

    #[test]
    fn names() {
        assert_eq!(<f32 as Scalar>::NAME, "f32");
        assert_eq!(<f64 as Scalar>::NAME, "f64");
    }

    #[test]
    fn f64_roundtrip() {
        let x = <f64 as Scalar>::from_f64c(0.125);
        assert_eq!(x, 0.125);
        assert_eq!(x.to_f64c(), 0.125);
    }
}
