//! Plain-text / markdown table rendering for benchmark reports.
//!
//! The benchmark harness regenerates the paper's tables (Table 4–6, the
//! Fig. 2 speedup grid, …) as aligned text so EXPERIMENTS.md entries can be
//! pasted directly from bench output.

/// A simple column-aligned table.
#[derive(Debug, Clone, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Table {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    pub fn num_rows(&self) -> usize {
        self.rows.len()
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.header.iter().map(|h| h.chars().count()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                w[i] = w[i].max(c.chars().count());
            }
        }
        w
    }

    /// Render as a markdown table.
    pub fn to_markdown(&self) -> String {
        let w = self.widths();
        let mut out = String::new();
        let fmt_row = |cells: &[String], w: &[usize]| -> String {
            let mut line = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                line.push_str(&format!(" {:<width$} |", c, width = w[i]));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.header, &w));
        let mut sep = String::from("|");
        for wi in &w {
            sep.push_str(&format!("{:-<width$}|", "", width = wi + 2));
        }
        sep.push('\n');
        out.push_str(&sep);
        for r in &self.rows {
            out.push_str(&fmt_row(r, &w));
        }
        out
    }

    /// Render as CSV.
    pub fn to_csv(&self) -> String {
        let esc = |c: &str| -> String {
            if c.contains(',') || c.contains('"') {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.to_string()
            }
        };
        let mut out = String::new();
        out.push_str(&self.header.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&r.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

/// Format a float with a sensible number of significant digits (paper style).
pub fn sig3(v: f64) -> String {
    if v == 0.0 || !v.is_finite() {
        return format!("{v}");
    }
    let mag = v.abs().log10().floor() as i32;
    let decimals = (2 - mag).max(0) as usize;
    format!("{:.*}", decimals, v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn markdown_shape() {
        let mut t = Table::new(&["#dims", "1k", "3k"]);
        t.row(vec!["1".into(), "15.7".into(), "43.5".into()]);
        let md = t.to_markdown();
        assert!(md.contains("| #dims | 1k   | 3k   |"));
        assert_eq!(md.lines().count(), 3);
    }

    #[test]
    fn csv_escaping() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["x,y".into(), "q\"z".into()]);
        let csv = t.to_csv();
        assert!(csv.contains("\"x,y\""));
        assert!(csv.contains("\"q\"\"z\""));
    }

    #[test]
    #[should_panic]
    fn arity_checked() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn sig3_formats() {
        assert_eq!(sig3(15.666), "15.7");
        assert_eq!(sig3(0.0123), "0.0123");
        assert_eq!(sig3(516.0), "516");
    }
}
