//! Elman RNN: `h' = tanh(W x + U h + b)` — the simplest non-linear
//! recurrence; used as the test vehicle for DEER invariants because its
//! Jacobian `diag(1 − h'²)·U` is trivially verifiable.

use super::{init_uniform, Cell, CellGrad};
use crate::util::rng::Rng;
use crate::util::scalar::Scalar;

/// Elman cell. Parameter layout: `[W (n·m), U (n·n), b (n)]`.
#[derive(Debug, Clone)]
pub struct Elman<S> {
    n: usize,
    m: usize,
    p: Vec<S>,
}

impl<S: Scalar> Elman<S> {
    pub fn new(n: usize, m: usize, rng: &mut Rng) -> Self {
        let mut p = vec![S::zero(); n * m + n * n + n];
        init_uniform(&mut p, n, rng);
        Elman { n, m, p }
    }

    pub fn from_params(n: usize, m: usize, p: Vec<S>) -> Self {
        assert_eq!(p.len(), n * m + n * n + n);
        Elman { n, m, p }
    }

    fn w(&self) -> &[S] {
        &self.p[..self.n * self.m]
    }
    fn u(&self) -> &[S] {
        &self.p[self.n * self.m..self.n * self.m + self.n * self.n]
    }
    fn b(&self) -> &[S] {
        &self.p[self.n * self.m + self.n * self.n..]
    }

    #[inline]
    fn preact(&self, h: &[S], x: &[S], out: &mut [S]) {
        let (n, m) = (self.n, self.m);
        let (w, u, b) = (self.w(), self.u(), self.b());
        for i in 0..n {
            let mut a = b[i];
            let roww = &w[i * m..(i + 1) * m];
            for j in 0..m {
                a += roww[j] * x[j];
            }
            let rowu = &u[i * n..(i + 1) * n];
            for j in 0..n {
                a += rowu[j] * h[j];
            }
            out[i] = a;
        }
    }
}

impl<S: Scalar> Cell<S> for Elman<S> {
    fn state_dim(&self) -> usize {
        self.n
    }
    fn input_dim(&self) -> usize {
        self.m
    }
    fn ws_len(&self) -> usize {
        self.n
    }

    fn step(&self, h: &[S], x: &[S], out: &mut [S], ws: &mut [S]) {
        self.preact(h, x, ws);
        for i in 0..self.n {
            out[i] = ws[i].tanh();
        }
    }

    fn jacobian(&self, h: &[S], x: &[S], out_f: &mut [S], out_jac: &mut [S], ws: &mut [S]) {
        let n = self.n;
        self.preact(h, x, ws);
        let u = self.u();
        for i in 0..n {
            let f = ws[i].tanh();
            out_f[i] = f;
            let d = S::one() - f * f;
            let rowu = &u[i * n..(i + 1) * n];
            let jrow = &mut out_jac[i * n..(i + 1) * n];
            for j in 0..n {
                jrow[j] = d * rowu[j];
            }
        }
    }

    fn flops_step(&self) -> u64 {
        let (n, m) = (self.n as u64, self.m as u64);
        2 * n * (n + m) + 2 * n
    }

    fn flops_jacobian(&self) -> u64 {
        let n = self.n as u64;
        self.flops_step() + n * n + 2 * n
    }
}

impl<S: Scalar> CellGrad<S> for Elman<S> {
    fn num_params(&self) -> usize {
        self.p.len()
    }
    fn params(&self) -> &[S] {
        &self.p
    }
    fn params_mut(&mut self) -> &mut [S] {
        &mut self.p
    }

    fn vjp_step(
        &self,
        h: &[S],
        x: &[S],
        lambda: &[S],
        dh: &mut [S],
        mut dx: Option<&mut [S]>,
        dtheta: &mut [S],
        ws: &mut [S],
    ) {
        let (n, m) = (self.n, self.m);
        self.preact(h, x, ws);
        let u = self.u();
        let w = self.w();
        let off_u = n * m;
        let off_b = n * m + n * n;
        for i in 0..n {
            let f = ws[i].tanh();
            let da = lambda[i] * (S::one() - f * f);
            let rowu = &u[i * n..(i + 1) * n];
            for j in 0..n {
                dh[j] += rowu[j] * da;
                dtheta[off_u + i * n + j] += da * h[j];
            }
            if let Some(dx) = dx.as_deref_mut() {
                let roww = &w[i * m..(i + 1) * m];
                for j in 0..m {
                    dx[j] += roww[j] * da;
                }
            }
            for j in 0..m {
                dtheta[i * m + j] += da * x[j];
            }
            dtheta[off_b + i] += da;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cells::test_support::{check_jacobian, check_vjp};

    #[test]
    fn jacobian_matches_fd() {
        let mut rng = Rng::new(3);
        for &(n, m) in &[(1usize, 1usize), (3, 2), (5, 5)] {
            let cell: Elman<f64> = Elman::new(n, m, &mut rng);
            check_jacobian(&cell, n as u64, 1e-7);
        }
    }

    #[test]
    fn vjp_matches_fd() {
        let mut rng = Rng::new(4);
        let cell: Elman<f64> = Elman::new(4, 3, &mut rng);
        check_vjp(&cell, 77, 1e-6);
    }

    #[test]
    fn tanh_saturation_flattens_jacobian() {
        // Huge bias saturates tanh → Jacobian ≈ 0.
        let n = 2;
        let mut p = vec![0.0f64; n * 1 + n * n + n];
        p[n * 1 + n * n] = 50.0;
        p[n * 1 + n * n + 1] = 50.0;
        let cell = Elman::from_params(n, 1, p);
        let mut f = vec![0.0; n];
        let mut jac = vec![0.0; n * n];
        let mut ws = vec![0.0; n];
        cell.jacobian(&[0.3, -0.4], &[0.0], &mut f, &mut jac, &mut ws);
        assert!(jac.iter().all(|v| v.abs() < 1e-10));
        assert!(f.iter().all(|v| (v - 1.0).abs() < 1e-10));
    }
}
