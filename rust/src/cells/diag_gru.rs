//! GRU with **diagonal recurrent weights** — the ParaRNN-style variant
//! whose state Jacobian is *natively diagonal*, so DEER's Full mode is
//! exact Newton entirely through the O(n) packed kernels of
//! [`crate::scan::diag`] (no `DiagonalApprox` needed).
//!
//! Equations (the standard GRU with `W_h* = diag(u_*)`):
//!
//! ```text
//! r  = σ(W_ir x + b_ir + b_hr + u_r ⊙ h)
//! z  = σ(W_iz x + b_iz + b_hz + u_z ⊙ h)
//! m  = u_n ⊙ h + b_hn
//! ñ  = tanh(W_in x + b_in + r ⊙ m)
//! h' = (1 − z) ⊙ ñ + z ⊙ h
//! ```
//!
//! Every gate of unit `i` reads only `h_i`, so
//!
//! ```text
//! ∂h'_i/∂h_j = δ_ij [ c1·u_n_i + c2·u_r_i + c3·u_z_i + z_i ]
//! c1 = (1−z)(1−ñ²)r,  c2 = (1−z)(1−ñ²)m·r(1−r),  c3 = (h−ñ)·z(1−z)
//! ```
//!
//! — the exact coefficients of the dense [`super::Gru`] Jacobian restricted
//! to the diagonal. A `DiagGru` is numerically identical (bitwise, up to
//! signed zeros) to a [`super::Gru`] whose `W_h*` are the diagonal
//! embeddings of `u_*`; the tests pin that equivalence.

use super::{init_uniform, sigmoid, Cell, CellGrad, JacobianStructure};
use crate::util::rng::Rng;
use crate::util::scalar::Scalar;

/// Diagonal-recurrence GRU with a flat parameter vector.
///
/// Layout: `[W_ir, W_iz, W_in] (3·n·m)`, `[u_r, u_z, u_n] (3·n)`,
/// `[b_ir, b_iz, b_in, b_hr, b_hz, b_hn] (6·n)`.
#[derive(Debug, Clone)]
pub struct DiagGru<S> {
    n: usize,
    m: usize,
    p: Vec<S>,
}

// Workspace layout offsets (ws_len = 4n): r (n) | z (n) | m (n) | ñ (n)

impl<S: Scalar> DiagGru<S> {
    /// New cell with `n` hidden units and `m` inputs, uniform(-1/√n) init;
    /// the recurrent gains are shrunk inside the unit circle like
    /// [`super::IndRnn`] so long sequences neither blow up nor saturate.
    pub fn new(n: usize, m: usize, rng: &mut Rng) -> Self {
        let mut p = vec![S::zero(); 3 * n * m + 3 * n + 6 * n];
        init_uniform(&mut p, n, rng);
        let u_lo = 3 * n * m;
        for v in p[u_lo..u_lo + 3 * n].iter_mut() {
            *v = *v * S::from_f64c(0.9);
        }
        DiagGru { n, m, p }
    }

    /// Construct from an existing flat parameter vector.
    pub fn from_params(n: usize, m: usize, p: Vec<S>) -> Self {
        assert_eq!(p.len(), 3 * n * m + 3 * n + 6 * n);
        DiagGru { n, m, p }
    }

    #[inline]
    fn w_i(&self, k: usize) -> &[S] {
        let (n, m) = (self.n, self.m);
        &self.p[k * n * m..(k + 1) * n * m]
    }
    #[inline]
    fn u(&self, k: usize) -> &[S] {
        let (n, m) = (self.n, self.m);
        let base = 3 * n * m;
        &self.p[base + k * n..base + (k + 1) * n]
    }
    #[inline]
    fn b(&self, k: usize) -> &[S] {
        let (n, m) = (self.n, self.m);
        let base = 3 * n * m + 3 * n;
        &self.p[base + k * n..base + (k + 1) * n]
    }
    fn off_w_i(&self, k: usize) -> usize {
        k * self.n * self.m
    }
    fn off_u(&self, k: usize) -> usize {
        3 * self.n * self.m + k * self.n
    }
    fn off_b(&self, k: usize) -> usize {
        3 * self.n * self.m + 3 * self.n + k * self.n
    }

    /// Gate activations into ws: `[r, z, m, ñ]` each length n. The
    /// pre-activation base `[a_r, a_z, a_n]` is either computed inline from
    /// `x` (direct path, `pre = None`) or read from the trajectory-invariant
    /// projections of [`Cell::precompute_x`] (`pre = Some`, `x` unused) —
    /// ONE implementation owns the bitwise-sensitive accumulation order
    /// (bias + W·x first, then the `u ⊙ h` recurrent term), so the two
    /// paths cannot drift.
    #[inline]
    fn gates(&self, h: &[S], x: &[S], pre: Option<&[S]>, ws: &mut [S]) {
        let n = self.n;
        let m = self.m;
        let (u_r, u_z, u_n) = (self.u(0), self.u(1), self.u(2));
        let b_hn = self.b(5);
        for i in 0..n {
            let (ar, az, an) = match pre {
                Some(p) => (p[i], p[n + i], p[2 * n + i]),
                None => {
                    let (w_ir, w_iz, w_in) = (self.w_i(0), self.w_i(1), self.w_i(2));
                    let (b_ir, b_iz, b_in) = (self.b(0), self.b(1), self.b(2));
                    let (b_hr, b_hz) = (self.b(3), self.b(4));
                    let mut ar = b_ir[i] + b_hr[i];
                    let mut az = b_iz[i] + b_hz[i];
                    let mut an = b_in[i];
                    let (rowr, rowz, rown) = (
                        &w_ir[i * m..(i + 1) * m],
                        &w_iz[i * m..(i + 1) * m],
                        &w_in[i * m..(i + 1) * m],
                    );
                    for j in 0..m {
                        let xj = x[j];
                        ar += rowr[j] * xj;
                        az += rowz[j] * xj;
                        an += rown[j] * xj;
                    }
                    (ar, az, an)
                }
            };
            let hi = h[i];
            let r = sigmoid(ar + u_r[i] * hi);
            let z = sigmoid(az + u_z[i] * hi);
            let hm = b_hn[i] + u_n[i] * hi;
            ws[i] = r;
            ws[n + i] = z;
            ws[2 * n + i] = hm;
            ws[3 * n + i] = (an + r * hm).tanh();
        }
    }

    /// Shared tail of the Jacobian kernels: f and the packed diagonal from
    /// the gate values — the exact per-diagonal expression of the dense
    /// [`super::Gru`] kernel (`c1·u_n + c2·u_r + c3·u_z`, then `+ z`).
    #[inline]
    fn diag_from_gates(&self, h: &[S], out_f: &mut [S], out_jdiag: &mut [S], ws: &[S]) {
        let n = self.n;
        let (u_r, u_z, u_n) = (self.u(0), self.u(1), self.u(2));
        for i in 0..n {
            let r = ws[i];
            let z = ws[n + i];
            let mg = ws[2 * n + i];
            let nh = ws[3 * n + i];
            out_f[i] = (S::one() - z) * nh + z * h[i];
            let dn = S::one() - nh * nh;
            let dr = r * (S::one() - r);
            let dz = z * (S::one() - z);
            let c1 = (S::one() - z) * dn * r;
            let c2 = (S::one() - z) * dn * mg * dr;
            let c3 = (h[i] - nh) * dz;
            let mut d = c1 * u_n[i] + c2 * u_r[i] + c3 * u_z[i];
            d += z;
            out_jdiag[i] = d;
        }
    }
}

impl<S: Scalar> Cell<S> for DiagGru<S> {
    fn state_dim(&self) -> usize {
        self.n
    }
    fn input_dim(&self) -> usize {
        self.m
    }
    fn ws_len(&self) -> usize {
        4 * self.n
    }

    fn jacobian_structure(&self) -> JacobianStructure {
        JacobianStructure::Diagonal
    }

    fn step(&self, h: &[S], x: &[S], out: &mut [S], ws: &mut [S]) {
        let n = self.n;
        self.gates(h, x, None, ws);
        for i in 0..n {
            let (z, nh) = (ws[n + i], ws[3 * n + i]);
            out[i] = (S::one() - z) * nh + z * h[i];
        }
    }

    fn jacobian(&self, h: &[S], x: &[S], out_f: &mut [S], out_jac: &mut [S], ws: &mut [S]) {
        // Dense emission kept for the generic path: diag embedded in n×n.
        let n = self.n;
        for v in out_jac.iter_mut() {
            *v = S::zero();
        }
        self.gates(h, x, None, ws);
        let mut jd = vec![S::zero(); n];
        self.diag_from_gates(h, out_f, &mut jd, &ws[..4 * n]);
        for i in 0..n {
            out_jac[i * n + i] = jd[i];
        }
    }

    fn jacobian_diag(&self, h: &[S], x: &[S], out_f: &mut [S], out_jdiag: &mut [S], ws: &mut [S]) {
        self.gates(h, x, None, ws);
        let (gv, _) = ws.split_at(4 * self.n);
        self.diag_from_gates(h, out_f, out_jdiag, gv);
    }

    fn x_precompute_len(&self) -> usize {
        3 * self.n
    }

    /// `out[t] = [a_r, a_z, a_n]` input projections with the recurrent-free
    /// biases folded in — identical layout and accumulation order to
    /// [`super::Gru::precompute_x`].
    fn precompute_x(&self, xs: &[S], out: &mut [S]) {
        let n = self.n;
        let m = self.m;
        let t_len = xs.len() / m;
        debug_assert_eq!(out.len(), t_len * 3 * n);
        let (w_ir, w_iz, w_in) = (self.w_i(0), self.w_i(1), self.w_i(2));
        let (b_ir, b_iz, b_in) = (self.b(0), self.b(1), self.b(2));
        let (b_hr, b_hz) = (self.b(3), self.b(4));
        for t in 0..t_len {
            let x = &xs[t * m..(t + 1) * m];
            let o = &mut out[t * 3 * n..(t + 1) * 3 * n];
            for i in 0..n {
                let mut ar = b_ir[i] + b_hr[i];
                let mut az = b_iz[i] + b_hz[i];
                let mut an = b_in[i];
                let (rowr, rowz, rown) = (
                    &w_ir[i * m..(i + 1) * m],
                    &w_iz[i * m..(i + 1) * m],
                    &w_in[i * m..(i + 1) * m],
                );
                for j in 0..m {
                    let xj = x[j];
                    ar += rowr[j] * xj;
                    az += rowz[j] * xj;
                    an += rown[j] * xj;
                }
                o[i] = ar;
                o[n + i] = az;
                o[2 * n + i] = an;
            }
        }
    }

    fn jacobian_pre(&self, h: &[S], pre: &[S], out_f: &mut [S], out_jac: &mut [S], ws: &mut [S]) {
        let n = self.n;
        for v in out_jac.iter_mut() {
            *v = S::zero();
        }
        self.gates(h, &[], Some(pre), ws);
        let mut jd = vec![S::zero(); n];
        self.diag_from_gates(h, out_f, &mut jd, &ws[..4 * n]);
        for i in 0..n {
            out_jac[i * n + i] = jd[i];
        }
    }

    fn jacobian_diag_pre(
        &self,
        h: &[S],
        pre: &[S],
        out_f: &mut [S],
        out_jdiag: &mut [S],
        ws: &mut [S],
    ) {
        self.gates(h, &[], Some(pre), ws);
        let (gv, _) = ws.split_at(4 * self.n);
        self.diag_from_gates(h, out_f, out_jdiag, gv);
    }

    /// Fused batched step: the recurrence is elementwise, so the unit loop
    /// is outermost and each input-weight row streams across all B
    /// elements. Per-element accumulation order is identical to
    /// [`DiagGru::gates`], so the result is **bitwise** equal to the
    /// looped default.
    fn step_batch(&self, hs: &[S], xs: &[S], out: &mut [S], ws: &mut [S], batch: usize) {
        let n = self.n;
        let m = self.m;
        let _ = ws;
        debug_assert_eq!(hs.len(), batch * n);
        debug_assert_eq!(xs.len(), batch * m);
        debug_assert_eq!(out.len(), batch * n);
        let (w_ir, w_iz, w_in) = (self.w_i(0), self.w_i(1), self.w_i(2));
        let (u_r, u_z, u_n) = (self.u(0), self.u(1), self.u(2));
        let (b_ir, b_iz, b_in) = (self.b(0), self.b(1), self.b(2));
        let (b_hr, b_hz, b_hn) = (self.b(3), self.b(4), self.b(5));
        for i in 0..n {
            let (rowr, rowz, rown) = (
                &w_ir[i * m..(i + 1) * m],
                &w_iz[i * m..(i + 1) * m],
                &w_in[i * m..(i + 1) * m],
            );
            for s in 0..batch {
                let x = &xs[s * m..(s + 1) * m];
                let mut ar = b_ir[i] + b_hr[i];
                let mut az = b_iz[i] + b_hz[i];
                let mut an = b_in[i];
                for j in 0..m {
                    let xj = x[j];
                    ar += rowr[j] * xj;
                    az += rowz[j] * xj;
                    an += rown[j] * xj;
                }
                let hi = hs[s * n + i];
                let r = sigmoid(ar + u_r[i] * hi);
                let z = sigmoid(az + u_z[i] * hi);
                let hm = b_hn[i] + u_n[i] * hi;
                let nh = (an + r * hm).tanh();
                out[s * n + i] = (S::one() - z) * nh + z * hi;
            }
        }
    }

    /// Fused batched packed-diagonal Jacobian — projects each element's
    /// input and delegates to the fused [`Cell::jacobian_diag_pre_batch`]
    /// kernel. Not a hot path (FUNCEVAL hoists the projections), so the
    /// scratch allocation is fine.
    fn jacobian_diag_batch(
        &self,
        hs: &[S],
        xs: &[S],
        out_f: &mut [S],
        out_jdiag: &mut [S],
        ws: &mut [S],
        batch: usize,
    ) {
        let m = self.m;
        let pl = 3 * self.n;
        debug_assert_eq!(xs.len(), batch * m);
        let mut pres = vec![S::zero(); batch * pl];
        for s in 0..batch {
            self.precompute_x(&xs[s * m..(s + 1) * m], &mut pres[s * pl..(s + 1) * pl]);
        }
        self.jacobian_diag_pre_batch(hs, &pres, out_f, out_jdiag, ws, batch);
    }

    /// Fused batched [`Cell::jacobian_diag_pre`] — the FUNCEVAL hot kernel
    /// of the natively-diagonal path: the recurrence is elementwise, so
    /// the unit loop is outermost and each `u_*[i]` streams across all B
    /// elements. Per-element arithmetic is identical to the looped
    /// default, hence **bitwise** equal — the driver's fused-vs-per-element
    /// dispatch never changes numerics.
    fn jacobian_diag_pre_batch(
        &self,
        hs: &[S],
        pres: &[S],
        out_f: &mut [S],
        out_jdiag: &mut [S],
        ws: &mut [S],
        batch: usize,
    ) {
        let n = self.n;
        let _ = ws;
        debug_assert_eq!(hs.len(), batch * n);
        debug_assert_eq!(pres.len(), batch * 3 * n);
        debug_assert_eq!(out_f.len(), batch * n);
        debug_assert_eq!(out_jdiag.len(), batch * n);
        let (u_r, u_z, u_n) = (self.u(0), self.u(1), self.u(2));
        let b_hn = self.b(5);
        for i in 0..n {
            let (ur, uz, un) = (u_r[i], u_z[i], u_n[i]);
            for s in 0..batch {
                let pre = &pres[s * 3 * n..(s + 1) * 3 * n];
                let hi = hs[s * n + i];
                let r = sigmoid(pre[i] + ur * hi);
                let z = sigmoid(pre[n + i] + uz * hi);
                let mg = b_hn[i] + un * hi;
                let nh = (pre[2 * n + i] + r * mg).tanh();
                out_f[s * n + i] = (S::one() - z) * nh + z * hi;
                let dn = S::one() - nh * nh;
                let dr = r * (S::one() - r);
                let dz = z * (S::one() - z);
                let c1 = (S::one() - z) * dn * r;
                let c2 = (S::one() - z) * dn * mg * dr;
                let c3 = (hi - nh) * dz;
                let mut d = c1 * un + c2 * ur + c3 * uz;
                d += z;
                out_jdiag[s * n + i] = d;
            }
        }
    }

    fn flops_step(&self) -> u64 {
        let (n, m) = (self.n as u64, self.m as u64);
        // three input matvecs + elementwise gates/recurrence
        2 * 3 * n * m + 18 * n
    }

    fn flops_jacobian(&self) -> u64 {
        let n = self.n as u64;
        self.flops_step() + 14 * n
    }
}

impl<S: Scalar> CellGrad<S> for DiagGru<S> {
    fn num_params(&self) -> usize {
        self.p.len()
    }
    fn params(&self) -> &[S] {
        &self.p
    }
    fn params_mut(&mut self) -> &mut [S] {
        &mut self.p
    }

    fn vjp_step(
        &self,
        h: &[S],
        x: &[S],
        lambda: &[S],
        dh: &mut [S],
        mut dx: Option<&mut [S]>,
        dtheta: &mut [S],
        ws: &mut [S],
    ) {
        let n = self.n;
        let m = self.m;
        self.gates(h, x, None, ws);

        // per-unit adjoints, as in the dense GRU: da_r / da_z are the gate
        // pre-activation adjoints, dc the tanh input-part adjoint (== d
        // b_in), dm the adjoint of m = u_n ⊙ h + b_hn
        let mut da_r = vec![S::zero(); n];
        let mut da_z = vec![S::zero(); n];
        let mut dc = vec![S::zero(); n];
        let mut dm = vec![S::zero(); n];
        let (u_r, u_z, u_n) = (self.u(0), self.u(1), self.u(2));
        for i in 0..n {
            let r = ws[i];
            let z = ws[n + i];
            let mg = ws[2 * n + i];
            let nh = ws[3 * n + i];
            let lam = lambda[i];
            dh[i] += lam * z;
            let dnh = lam * (S::one() - z);
            let dzg = lam * (h[i] - nh);
            let du = dnh * (S::one() - nh * nh);
            dc[i] = du;
            dm[i] = du * r;
            da_r[i] = du * mg * (r * (S::one() - r));
            da_z[i] = dzg * (z * (S::one() - z));
            // elementwise recurrent paths
            dh[i] += u_r[i] * da_r[i] + u_z[i] * da_z[i] + u_n[i] * dm[i];
        }

        if let Some(dx) = dx.as_deref_mut() {
            let (w_ir, w_iz, w_in) = (self.w_i(0), self.w_i(1), self.w_i(2));
            for i in 0..n {
                let (ar, az, ac) = (da_r[i], da_z[i], dc[i]);
                let (rowir, rowiz, rowin) = (
                    &w_ir[i * m..(i + 1) * m],
                    &w_iz[i * m..(i + 1) * m],
                    &w_in[i * m..(i + 1) * m],
                );
                for j in 0..m {
                    dx[j] += rowir[j] * ar + rowiz[j] * az + rowin[j] * ac;
                }
            }
        }

        let (o_wir, o_wiz, o_win) = (self.off_w_i(0), self.off_w_i(1), self.off_w_i(2));
        let (o_ur, o_uz, o_un) = (self.off_u(0), self.off_u(1), self.off_u(2));
        for i in 0..n {
            let (ar, az, ac, am) = (da_r[i], da_z[i], dc[i], dm[i]);
            for j in 0..m {
                let xj = x[j];
                dtheta[o_wir + i * m + j] += ar * xj;
                dtheta[o_wiz + i * m + j] += az * xj;
                dtheta[o_win + i * m + j] += ac * xj;
            }
            let hi = h[i];
            dtheta[o_ur + i] += ar * hi;
            dtheta[o_uz + i] += az * hi;
            dtheta[o_un + i] += am * hi;
            dtheta[self.off_b(0) + i] += ar; // b_ir
            dtheta[self.off_b(1) + i] += az; // b_iz
            dtheta[self.off_b(2) + i] += ac; // b_in
            dtheta[self.off_b(3) + i] += ar; // b_hr
            dtheta[self.off_b(4) + i] += az; // b_hz
            dtheta[self.off_b(5) + i] += am; // b_hn
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cells::test_support::{check_jacobian, check_vjp};
    use crate::cells::Gru;

    #[test]
    fn jacobian_matches_fd() {
        let mut rng = Rng::new(41);
        for &(n, m) in &[(1usize, 1usize), (3, 2), (6, 4)] {
            let cell: DiagGru<f64> = DiagGru::new(n, m, &mut rng);
            check_jacobian(&cell, 500 + n as u64, 1e-6);
        }
    }

    #[test]
    fn vjp_matches_fd() {
        let mut rng = Rng::new(42);
        for &(n, m) in &[(1usize, 2usize), (4, 3)] {
            let cell: DiagGru<f64> = DiagGru::new(n, m, &mut rng);
            check_vjp(&cell, 600 + n as u64, 1e-6);
        }
    }

    #[test]
    fn structure_reported_diagonal() {
        let mut rng = Rng::new(43);
        let cell: DiagGru<f64> = DiagGru::new(3, 2, &mut rng);
        assert_eq!(cell.jacobian_structure(), JacobianStructure::Diagonal);
        assert_eq!(cell.x_precompute_len(), 9);
    }

    /// Build the dense [`Gru`] whose `W_h*` are the diagonal embeddings of
    /// this cell's `u_*` (same `W_i*` and biases).
    fn dense_twin(cell: &DiagGru<f64>) -> Gru<f64> {
        let (n, m) = (cell.n, cell.m);
        let mut p = vec![0.0; 3 * n * m + 3 * n * n + 6 * n];
        p[..3 * n * m].copy_from_slice(&cell.p[..3 * n * m]);
        for k in 0..3 {
            let u = cell.u(k);
            for i in 0..n {
                p[3 * n * m + k * n * n + i * n + i] = u[i];
            }
        }
        let b_src = &cell.p[3 * n * m + 3 * n..];
        p[3 * n * m + 3 * n * n..].copy_from_slice(b_src);
        Gru::from_params(n, m, p)
    }

    /// The diagonal cell IS the dense GRU with diagonally-embedded
    /// recurrent weights: step, dense Jacobian and packed diagonal all
    /// agree (summing the embedded zeros changes nothing).
    #[test]
    fn matches_dense_gru_with_embedded_diagonal() {
        let mut rng = Rng::new(44);
        for &(n, m) in &[(1usize, 1usize), (4, 3), (7, 2)] {
            let diag: DiagGru<f64> = DiagGru::new(n, m, &mut rng);
            let dense = dense_twin(&diag);
            let mut h = vec![0.0; n];
            let mut x = vec![0.0; m];
            rng.fill_normal(&mut h, 0.8);
            rng.fill_normal(&mut x, 1.0);
            let mut wsd = vec![0.0; diag.ws_len()];
            let mut wsg = vec![0.0; dense.ws_len()];

            let mut f1 = vec![0.0; n];
            let mut f2 = vec![0.0; n];
            diag.step(&h, &x, &mut f1, &mut wsd);
            dense.step(&h, &x, &mut f2, &mut wsg);
            assert_eq!(f1, f2, "n={n}: step");

            let mut jf = vec![0.0; n];
            let mut jd = vec![0.0; n];
            diag.jacobian_diag(&h, &x, &mut jf, &mut jd, &mut wsd);
            let mut gf = vec![0.0; n];
            let mut gjac = vec![0.0; n * n];
            dense.jacobian(&h, &x, &mut gf, &mut gjac, &mut wsg);
            assert_eq!(jf, gf, "n={n}: jacobian f");
            for i in 0..n {
                assert_eq!(jd[i], gjac[i * n + i], "n={n}: diag entry {i}");
                for j in 0..n {
                    if i != j {
                        assert_eq!(gjac[i * n + j], 0.0, "n={n}: off-diag ({i},{j})");
                    }
                }
            }
        }
    }

    /// Packed diagonal vs dense emission, and the precomputed-input paths,
    /// all bitwise equal to the direct kernels.
    #[test]
    fn packed_and_pre_paths_match_bitwise() {
        let mut rng = Rng::new(45);
        let (n, m, t) = (5usize, 3usize, 7usize);
        let cell: DiagGru<f64> = DiagGru::new(n, m, &mut rng);
        let mut xs = vec![0.0; t * m];
        rng.fill_normal(&mut xs, 1.0);
        let mut pre = vec![0.0; t * cell.x_precompute_len()];
        cell.precompute_x(&xs, &mut pre);
        let mut h = vec![0.0; n];
        rng.fill_normal(&mut h, 0.6);
        let mut ws = vec![0.0; cell.ws_len()];
        let pl = cell.x_precompute_len();
        for i in 0..t {
            let x = &xs[i * m..(i + 1) * m];
            let p = &pre[i * pl..(i + 1) * pl];
            let (mut f1, mut f2, mut f3) = (vec![0.0; n], vec![0.0; n], vec![0.0; n]);
            let (mut d1, mut d2) = (vec![0.0; n], vec![0.0; n]);
            let mut jac = vec![0.0; n * n];
            cell.jacobian_diag(&h, x, &mut f1, &mut d1, &mut ws);
            cell.jacobian_diag_pre(&h, p, &mut f2, &mut d2, &mut ws);
            cell.jacobian_pre(&h, p, &mut f3, &mut jac, &mut ws);
            assert_eq!(f1, f2);
            assert_eq!(d1, d2);
            assert_eq!(f1, f3);
            for j in 0..n {
                assert_eq!(jac[j * n + j], d1[j]);
            }
        }
    }

    /// Fused batched kernels vs the looped defaults, bitwise.
    #[test]
    fn batched_kernels_match_looped_bitwise() {
        let mut rng = Rng::new(46);
        let (n, m, batch) = (4usize, 3usize, 5usize);
        let cell: DiagGru<f64> = DiagGru::new(n, m, &mut rng);
        let mut hs = vec![0.0; batch * n];
        let mut xs = vec![0.0; batch * m];
        rng.fill_normal(&mut hs, 0.7);
        rng.fill_normal(&mut xs, 1.0);
        let mut ws = vec![0.0; cell.ws_len()];

        let mut f_b = vec![0.0; batch * n];
        cell.step_batch(&hs, &xs, &mut f_b, &mut ws, batch);
        let pl = cell.x_precompute_len();
        let mut pres = vec![0.0; batch * pl];
        for s in 0..batch {
            cell.precompute_x(&xs[s * m..(s + 1) * m], &mut pres[s * pl..(s + 1) * pl]);
        }
        let mut jf_b = vec![0.0; batch * n];
        let mut jd_b = vec![0.0; batch * n];
        cell.jacobian_diag_pre_batch(&hs, &pres, &mut jf_b, &mut jd_b, &mut ws, batch);
        for s in 0..batch {
            let h = &hs[s * n..(s + 1) * n];
            let x = &xs[s * m..(s + 1) * m];
            let mut f = vec![0.0; n];
            cell.step(h, x, &mut f, &mut ws);
            assert_eq!(f, &f_b[s * n..(s + 1) * n], "seq {s}: step_batch");
            let mut jf = vec![0.0; n];
            let mut jd = vec![0.0; n];
            cell.jacobian_diag_pre(h, &pres[s * pl..(s + 1) * pl], &mut jf, &mut jd, &mut ws);
            assert_eq!(jf, &jf_b[s * n..(s + 1) * n], "seq {s}: pre_batch f");
            assert_eq!(jd, &jd_b[s * n..(s + 1) * n], "seq {s}: pre_batch diag");
        }
    }
}
