//! LEM — Long Expressive Memory (Rusch et al., 2021). The paper reproduces
//! LEM on EigenWorms (Table 1, "our reproducibility attempt") and uses it for
//! the equal-memory comparison of Fig. 8; DEER applies to it unchanged since
//! it is a plain non-linear recurrence over the packed state `s = [y, z]`.
//!
//! Discretised equations (Δt = 1):
//!
//! ```text
//! Δ̄t = σ(W₁ x + V₁ y + b₁)
//! Δ̂t = σ(W₂ x + V₂ y + b₂)
//! z' = (1 − Δ̄t) ⊙ z + Δ̄t ⊙ tanh(W_z x + V_z y + b_z)
//! y' = (1 − Δ̂t) ⊙ y + Δ̂t ⊙ tanh(W_y x + V_y z' + b_y)
//! ```

use super::{init_uniform, sigmoid, Cell, CellGrad};
use crate::util::rng::Rng;
use crate::util::scalar::Scalar;

/// LEM cell with `n` units per branch and `m` inputs; `state_dim() = 2n`
/// (packed `[y, z]`).
///
/// Parameter layout: `[W₁, W₂, W_z, W_y] (4·n·m)`, `[V₁, V₂, V_z, V_y]
/// (4·n·n)`, `[b₁, b₂, b_z, b_y] (4·n)`.
#[derive(Debug, Clone)]
pub struct Lem<S> {
    n: usize,
    m: usize,
    p: Vec<S>,
}

const K: usize = 4; // dt1, dt2, z-branch, y-branch

impl<S: Scalar> Lem<S> {
    pub fn new(n: usize, m: usize, rng: &mut Rng) -> Self {
        let mut p = vec![S::zero(); K * (n * m + n * n + n)];
        init_uniform(&mut p, n, rng);
        Lem { n, m, p }
    }

    pub fn from_params(n: usize, m: usize, p: Vec<S>) -> Self {
        assert_eq!(p.len(), K * (n * m + n * n + n));
        Lem { n, m, p }
    }

    fn w(&self, k: usize) -> &[S] {
        let (n, m) = (self.n, self.m);
        &self.p[k * n * m..(k + 1) * n * m]
    }
    fn v(&self, k: usize) -> &[S] {
        let (n, m) = (self.n, self.m);
        let base = K * n * m;
        &self.p[base + k * n * n..base + (k + 1) * n * n]
    }
    fn b(&self, k: usize) -> &[S] {
        let (n, m) = (self.n, self.m);
        let base = K * (n * m + n * n);
        &self.p[base + k * n..base + (k + 1) * n]
    }
    fn off_w(&self, k: usize) -> usize {
        k * self.n * self.m
    }
    fn off_v(&self, k: usize) -> usize {
        K * self.n * self.m + k * self.n * self.n
    }
    fn off_b(&self, k: usize) -> usize {
        K * (self.n * self.m + self.n * self.n) + k * self.n
    }

    /// `a = W_k x + V_k q + b_k` where q is y (k<3) or z' (k=3).
    #[inline]
    fn branch(&self, k: usize, q: &[S], x: &[S], out: &mut [S]) {
        let (n, m) = (self.n, self.m);
        let (w, v, b) = (self.w(k), self.v(k), self.b(k));
        for i in 0..n {
            let mut a = b[i];
            let roww = &w[i * m..(i + 1) * m];
            for j in 0..m {
                a += roww[j] * x[j];
            }
            let rowv = &v[i * n..(i + 1) * n];
            for j in 0..n {
                a += rowv[j] * q[j];
            }
            out[i] = a;
        }
    }

    /// Fill ws: [dt1, dt2, gz, zp, gy] (5n). gz = tanh(z-branch), gy uses z'.
    #[inline]
    fn forward_ws(&self, s: &[S], x: &[S], ws: &mut [S]) {
        let n = self.n;
        let y = &s[..n];
        let z = &s[n..2 * n];
        // split ws into 5 segments; compute in-place sequentially
        {
            let (dt1, rest) = ws.split_at_mut(n);
            let (dt2, rest) = rest.split_at_mut(n);
            let (gz, rest) = rest.split_at_mut(n);
            let (zp, _) = rest.split_at_mut(n);
            self.branch(0, y, x, dt1);
            self.branch(1, y, x, dt2);
            self.branch(2, y, x, gz);
            for i in 0..n {
                dt1[i] = sigmoid(dt1[i]);
                dt2[i] = sigmoid(dt2[i]);
                gz[i] = gz[i].tanh();
                zp[i] = (S::one() - dt1[i]) * z[i] + dt1[i] * gz[i];
            }
        }
        let zp_copy: Vec<S> = ws[3 * n..4 * n].to_vec();
        let gy = &mut ws[4 * n..5 * n];
        self.branch(3, &zp_copy, x, gy);
        for g in gy.iter_mut() {
            *g = g.tanh();
        }
    }
}

impl<S: Scalar> Cell<S> for Lem<S> {
    fn state_dim(&self) -> usize {
        2 * self.n
    }
    fn input_dim(&self) -> usize {
        self.m
    }
    fn ws_len(&self) -> usize {
        5 * self.n
    }

    fn step(&self, s: &[S], x: &[S], out: &mut [S], ws: &mut [S]) {
        let n = self.n;
        self.forward_ws(s, x, ws);
        let y = &s[..n];
        for i in 0..n {
            let dt2 = ws[n + i];
            out[i] = (S::one() - dt2) * y[i] + dt2 * ws[4 * n + i]; // y'
            out[n + i] = ws[3 * n + i]; // z'
        }
    }

    fn jacobian(&self, s: &[S], x: &[S], out_f: &mut [S], out_jac: &mut [S], ws: &mut [S]) {
        let n = self.n;
        let dim = 2 * n;
        self.forward_ws(s, x, ws);
        let y = &s[..n];
        let z = &s[n..2 * n];
        let (v1, v2, vz, vy) = (self.v(0), self.v(1), self.v(2), self.v(3));

        // z'-block derivatives: ∂z'/∂y (dense), ∂z'/∂z (diag(1−dt1))
        // dzp_dy[i][j] = (gz_i − z_i)·dt1_i(1−dt1_i)·V1[i,j] + dt1_i·(1−gz_i²)·Vz[i,j]
        let mut dzp_dy = vec![S::zero(); n * n];
        for i in 0..n {
            let dt1 = ws[i];
            let gz = ws[2 * n + i];
            let c1 = (gz - z[i]) * dt1 * (S::one() - dt1);
            let c2 = dt1 * (S::one() - gz * gz);
            let (r1, rz) = (&v1[i * n..(i + 1) * n], &vz[i * n..(i + 1) * n]);
            let row = &mut dzp_dy[i * n..(i + 1) * n];
            for j in 0..n {
                row[j] = c1 * r1[j] + c2 * rz[j];
            }
        }

        for i in 0..n {
            let dt1 = ws[i];
            let dt2 = ws[n + i];
            let gy = ws[4 * n + i];
            out_f[i] = (S::one() - dt2) * y[i] + dt2 * gy;
            out_f[n + i] = ws[3 * n + i];

            let c_dt2 = (gy - y[i]) * dt2 * (S::one() - dt2); // coeff of V2 rows
            let c_gy = dt2 * (S::one() - gy * gy); // coeff of V_y·∂z'/∂·
            let (r2, ry) = (&v2[i * n..(i + 1) * n], &vy[i * n..(i + 1) * n]);

            // ∂y'_i/∂y_j = (1−dt2)δ + c_dt2·V2[i,j] + c_gy·Σ_k Vy[i,k]·dzp_dy[k,j]
            for j in 0..n {
                let mut acc = c_dt2 * r2[j];
                let mut conv = S::zero();
                for k in 0..n {
                    conv += ry[k] * dzp_dy[k * n + j];
                }
                acc += c_gy * conv;
                if i == j {
                    acc += S::one() - dt2;
                }
                out_jac[i * dim + j] = acc;
                // ∂z'_i/∂y_j
                out_jac[(n + i) * dim + j] = dzp_dy[i * n + j];
            }
            // ∂y'_i/∂z_j = c_gy·Vy[i,j]·(1−dt1_j); ∂z'_i/∂z_j = (1−dt1_i)δ
            for j in 0..n {
                out_jac[i * dim + n + j] = c_gy * ry[j] * (S::one() - ws[j]);
                out_jac[(n + i) * dim + n + j] = S::zero();
            }
            out_jac[(n + i) * dim + n + i] = S::one() - dt1;
        }
    }

    fn flops_step(&self) -> u64 {
        let (n, m) = (self.n as u64, self.m as u64);
        2 * 4 * n * (n + m) + 16 * n
    }

    fn flops_jacobian(&self) -> u64 {
        let n = self.n as u64;
        // dominated by the V_y · ∂z'/∂y product: n³
        self.flops_step() + 2 * n * n * n + 8 * n * n
    }
}

impl<S: Scalar> CellGrad<S> for Lem<S> {
    fn num_params(&self) -> usize {
        self.p.len()
    }
    fn params(&self) -> &[S] {
        &self.p
    }
    fn params_mut(&mut self) -> &mut [S] {
        &mut self.p
    }

    fn vjp_step(
        &self,
        s: &[S],
        x: &[S],
        lambda: &[S],
        dh: &mut [S],
        mut dx: Option<&mut [S]>,
        dtheta: &mut [S],
        ws: &mut [S],
    ) {
        let n = self.n;
        let m = self.m;
        self.forward_ws(s, x, ws);
        let y = &s[..n];
        let z = &s[n..2 * n];
        let zp: Vec<S> = ws[3 * n..4 * n].to_vec();
        let (lam_y, lam_z) = lambda.split_at(n);

        let (v1, v2, vz, vy) = (self.v(0), self.v(1), self.v(2), self.v(3));

        // --- y' branch ---
        // y' = (1−dt2) y + dt2·gy,   gy = tanh(W_y x + V_y z' + b_y)
        let mut da2 = vec![S::zero(); n]; // pre-act adjoint of dt2 branch
        let mut day = vec![S::zero(); n]; // pre-act adjoint of y branch (tanh arg)
        let mut dzp = vec![S::zero(); n]; // adjoint of z'
        for i in 0..n {
            let dt2 = ws[n + i];
            let gy = ws[4 * n + i];
            dh[i] += lam_y[i] * (S::one() - dt2);
            da2[i] = lam_y[i] * (gy - y[i]) * dt2 * (S::one() - dt2);
            day[i] = lam_y[i] * dt2 * (S::one() - gy * gy);
        }
        // dzp += V_yᵀ day ; dh(y part) += V_2ᵀ da2
        for i in 0..n {
            let (a2, ay) = (da2[i], day[i]);
            let (r2, ry) = (&v2[i * n..(i + 1) * n], &vy[i * n..(i + 1) * n]);
            for j in 0..n {
                dh[j] += r2[j] * a2;
                dzp[j] += ry[j] * ay;
            }
        }
        // z' cotangent also flows directly from λ_z
        for i in 0..n {
            dzp[i] += lam_z[i];
        }

        // --- z' branch ---
        // z' = (1−dt1) z + dt1·gz,   gz = tanh(W_z x + V_z y + b_z)
        let mut da1 = vec![S::zero(); n];
        let mut daz = vec![S::zero(); n];
        for i in 0..n {
            let dt1 = ws[i];
            let gz = ws[2 * n + i];
            dh[n + i] += dzp[i] * (S::one() - dt1);
            da1[i] = dzp[i] * (gz - z[i]) * dt1 * (S::one() - dt1);
            daz[i] = dzp[i] * dt1 * (S::one() - gz * gz);
        }
        for i in 0..n {
            let (a1, az) = (da1[i], daz[i]);
            let (r1, rz) = (&v1[i * n..(i + 1) * n], &vz[i * n..(i + 1) * n]);
            for j in 0..n {
                dh[j] += r1[j] * a1 + rz[j] * az;
            }
        }

        // --- parameters and inputs ---
        // branch k uses carrier q_k ∈ {y, y, y, z'} and pre-act adjoint a_k.
        let adjoints = [&da1, &da2, &daz, &day];
        for k in 0..K {
            let a = adjoints[[0usize, 1, 2, 3][k]];
            // NOTE: branch order in params is [dt1, dt2, z, y] = [da1, da2, daz, day]
            let q: &[S] = if k == 3 { &zp } else { y };
            let w = self.w(k);
            let (ow, ov, ob) = (self.off_w(k), self.off_v(k), self.off_b(k));
            for i in 0..n {
                let ai = a[i];
                if ai == S::zero() {
                    continue;
                }
                for j in 0..m {
                    dtheta[ow + i * m + j] += ai * x[j];
                }
                for j in 0..n {
                    dtheta[ov + i * n + j] += ai * q[j];
                }
                dtheta[ob + i] += ai;
                if let Some(dx) = dx.as_deref_mut() {
                    let roww = &w[i * m..(i + 1) * m];
                    for j in 0..m {
                        dx[j] += roww[j] * ai;
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cells::test_support::{check_jacobian, check_vjp};

    #[test]
    fn jacobian_matches_fd() {
        let mut rng = Rng::new(13);
        for &(n, m) in &[(1usize, 1usize), (2, 2), (4, 3)] {
            let cell: Lem<f64> = Lem::new(n, m, &mut rng);
            check_jacobian(&cell, 500 + n as u64, 1e-6);
        }
    }

    #[test]
    fn vjp_matches_fd() {
        let mut rng = Rng::new(14);
        let cell: Lem<f64> = Lem::new(3, 2, &mut rng);
        check_vjp(&cell, 600, 1e-6);
    }

    #[test]
    fn convex_combination_property() {
        // Both state branches are convex combinations with tanh-bounded
        // targets, so |s'|∞ ≤ max(|s|∞, 1).
        let mut rng = Rng::new(15);
        let cell: Lem<f64> = Lem::new(6, 3, &mut rng);
        let mut s = vec![0.0; 12];
        let mut x = vec![0.0; 3];
        let mut out = vec![0.0; 12];
        let mut ws = vec![0.0; cell.ws_len()];
        for _ in 0..100 {
            rng.fill_normal(&mut x, 1.0);
            cell.step(&s, &x, &mut out, &mut ws);
            std::mem::swap(&mut s, &mut out);
            assert!(s.iter().all(|v| v.abs() <= 1.0 + 1e-12));
        }
    }
}
