//! LEM — Long Expressive Memory (Rusch et al., 2021). The paper reproduces
//! LEM on EigenWorms (Table 1, "our reproducibility attempt") and uses it for
//! the equal-memory comparison of Fig. 8; DEER applies to it unchanged since
//! it is a plain non-linear recurrence over the packed state, stored
//! **interleaved**: `s = [y_0, z_0, y_1, z_1, …]`, so each unit's coupled
//! `(y_i, z_i)` pair occupies one contiguous 2-slot block (the `Block(2)`
//! pairing the packed [`Cell::jacobian_block`] kernels exploit — exact when
//! the recurrent matrices `V_k` are diagonal, the `BlockApprox` quasi mode
//! otherwise).
//!
//! Discretised equations (Δt = 1):
//!
//! ```text
//! Δ̄t = σ(W₁ x + V₁ y + b₁)
//! Δ̂t = σ(W₂ x + V₂ y + b₂)
//! z' = (1 − Δ̄t) ⊙ z + Δ̄t ⊙ tanh(W_z x + V_z y + b_z)
//! y' = (1 − Δ̂t) ⊙ y + Δ̂t ⊙ tanh(W_y x + V_y z' + b_y)
//! ```
//!
//! The four input projections `W_k x + b_k` are trajectory-invariant, so
//! the cell supports [`Cell::precompute_x`] (4n per step).

use super::{init_uniform, sigmoid, Cell, CellGrad, JacobianStructure};
use crate::util::rng::Rng;
use crate::util::scalar::Scalar;

/// LEM cell with `n` units per branch and `m` inputs; `state_dim() = 2n`
/// (interleaved `[y_0, z_0, y_1, z_1, …]`).
///
/// Parameter layout: `[W₁, W₂, W_z, W_y] (4·n·m)`, `[V₁, V₂, V_z, V_y]
/// (4·n·n)`, `[b₁, b₂, b_z, b_y] (4·n)`.
#[derive(Debug, Clone)]
pub struct Lem<S> {
    n: usize,
    m: usize,
    p: Vec<S>,
}

const K: usize = 4; // dt1, dt2, z-branch, y-branch

// Workspace layout (ws_len = 8n):
// [dt1, dt2, gz, zp, gy] (5n) | unpacked y (n) | ws[6n..8n]: z'-staging for
// the y-branch during forward_ws, then block scratch c1s/c2s in
// jacobian_block_from_ws (the two uses never overlap in time)

impl<S: Scalar> Lem<S> {
    pub fn new(n: usize, m: usize, rng: &mut Rng) -> Self {
        let mut p = vec![S::zero(); K * (n * m + n * n + n)];
        init_uniform(&mut p, n, rng);
        Lem { n, m, p }
    }

    pub fn from_params(n: usize, m: usize, p: Vec<S>) -> Self {
        assert_eq!(p.len(), K * (n * m + n * n + n));
        Lem { n, m, p }
    }

    fn w(&self, k: usize) -> &[S] {
        let (n, m) = (self.n, self.m);
        &self.p[k * n * m..(k + 1) * n * m]
    }
    fn v(&self, k: usize) -> &[S] {
        let (n, m) = (self.n, self.m);
        let base = K * n * m;
        &self.p[base + k * n * n..base + (k + 1) * n * n]
    }
    fn b(&self, k: usize) -> &[S] {
        let (n, m) = (self.n, self.m);
        let base = K * (n * m + n * n);
        &self.p[base + k * n..base + (k + 1) * n]
    }
    fn off_w(&self, k: usize) -> usize {
        k * self.n * self.m
    }
    fn off_v(&self, k: usize) -> usize {
        K * self.n * self.m + k * self.n * self.n
    }
    fn off_b(&self, k: usize) -> usize {
        K * (self.n * self.m + self.n * self.n) + k * self.n
    }

    /// `a = W_k x + V_k q + b_k` where q is y (k<3) or z' (k=3). The
    /// `W_k x + b_k` base is either computed inline from `x` (`pre_k =
    /// None`) or read from the trajectory-invariant projections of
    /// [`Cell::precompute_x`] (`pre_k = Some`, `x` unused) — ONE
    /// implementation owns the bitwise-sensitive accumulation order
    /// (bias + W·x first, then V·q), so the two paths cannot drift.
    #[inline]
    fn branch(&self, k: usize, q: &[S], x: &[S], pre_k: Option<&[S]>, out: &mut [S]) {
        let (n, m) = (self.n, self.m);
        let v = self.v(k);
        for i in 0..n {
            let mut a = match pre_k {
                Some(p) => p[i],
                None => {
                    let (w, b) = (self.w(k), self.b(k));
                    let mut a = b[i];
                    let roww = &w[i * m..(i + 1) * m];
                    for j in 0..m {
                        a += roww[j] * x[j];
                    }
                    a
                }
            };
            let rowv = &v[i * n..(i + 1) * n];
            for j in 0..n {
                a += rowv[j] * q[j];
            }
            out[i] = a;
        }
    }

    /// Fill ws[..5n]: [dt1, dt2, gz, zp, gy], plus the unpacked contiguous
    /// y copy at ws[5n..6n]. gz = tanh(z-branch), gy uses z'. `z_i` is read
    /// straight from the interleaved state (`s[2i+1]`). `pre` selects the
    /// direct (`None`, from `x`) or precomputed-projection path per
    /// [`Lem::branch`].
    #[inline]
    fn forward_ws(&self, s: &[S], x: &[S], pre: Option<&[S]>, ws: &mut [S]) {
        let n = self.n;
        let (work, tail) = ws.split_at_mut(5 * n);
        let (ybuf, zbuf_tail) = tail.split_at_mut(n);
        for i in 0..n {
            ybuf[i] = s[2 * i];
        }
        let ybuf = &ybuf[..];
        {
            let (dt1, rest) = work.split_at_mut(n);
            let (dt2, rest) = rest.split_at_mut(n);
            let (gz, rest) = rest.split_at_mut(n);
            let (zp, _) = rest.split_at_mut(n);
            self.branch(0, ybuf, x, pre.map(|p| &p[..n]), dt1);
            self.branch(1, ybuf, x, pre.map(|p| &p[n..2 * n]), dt2);
            self.branch(2, ybuf, x, pre.map(|p| &p[2 * n..3 * n]), gz);
            for i in 0..n {
                dt1[i] = sigmoid(dt1[i]);
                dt2[i] = sigmoid(dt2[i]);
                gz[i] = gz[i].tanh();
                zp[i] = (S::one() - dt1[i]) * s[2 * i + 1] + dt1[i] * gz[i];
            }
        }
        // z' feeds the y-branch as its carrier; stage it in the workspace
        // tail (ws[6n..7n], dead outside this call) — no allocation on the
        // FUNCEVAL hot path.
        let zbuf = &mut zbuf_tail[..n];
        zbuf.copy_from_slice(&work[3 * n..4 * n]);
        let zbuf = &zbuf[..];
        let gy = &mut work[4 * n..5 * n];
        self.branch(3, zbuf, x, pre.map(|p| &p[3 * n..4 * n]), gy);
        for g in gy.iter_mut() {
            *g = g.tanh();
        }
    }

    /// Shared tail of the dense Jacobian kernels (after [`Lem::forward_ws`]
    /// filled `ws`).
    #[inline]
    fn jacobian_from_ws(&self, s: &[S], out_f: &mut [S], out_jac: &mut [S], ws: &[S]) {
        let n = self.n;
        let dim = 2 * n;
        let (v1, v2, vz, vy) = (self.v(0), self.v(1), self.v(2), self.v(3));

        // z'-block derivatives: ∂z'/∂y (dense), ∂z'/∂z (diag(1−dt1))
        // dzp_dy[i][j] = (gz_i − z_i)·dt1_i(1−dt1_i)·V1[i,j] + dt1_i·(1−gz_i²)·Vz[i,j]
        let mut dzp_dy = vec![S::zero(); n * n];
        for i in 0..n {
            let dt1 = ws[i];
            let gz = ws[2 * n + i];
            let c1 = (gz - s[2 * i + 1]) * dt1 * (S::one() - dt1);
            let c2 = dt1 * (S::one() - gz * gz);
            let (r1, rz) = (&v1[i * n..(i + 1) * n], &vz[i * n..(i + 1) * n]);
            let row = &mut dzp_dy[i * n..(i + 1) * n];
            for j in 0..n {
                row[j] = c1 * r1[j] + c2 * rz[j];
            }
        }

        for i in 0..n {
            let dt1 = ws[i];
            let dt2 = ws[n + i];
            let gy = ws[4 * n + i];
            let yi = s[2 * i];
            out_f[2 * i] = (S::one() - dt2) * yi + dt2 * gy;
            out_f[2 * i + 1] = ws[3 * n + i];

            let c_dt2 = (gy - yi) * dt2 * (S::one() - dt2); // coeff of V2 rows
            let c_gy = dt2 * (S::one() - gy * gy); // coeff of V_y·∂z'/∂·
            let (r2, ry) = (&v2[i * n..(i + 1) * n], &vy[i * n..(i + 1) * n]);

            // ∂y'_i/∂y_j = (1−dt2)δ + c_dt2·V2[i,j] + c_gy·Σ_k Vy[i,k]·dzp_dy[k,j]
            for j in 0..n {
                let mut acc = c_dt2 * r2[j];
                let mut conv = S::zero();
                for k in 0..n {
                    conv += ry[k] * dzp_dy[k * n + j];
                }
                acc += c_gy * conv;
                if i == j {
                    acc += S::one() - dt2;
                }
                out_jac[(2 * i) * dim + 2 * j] = acc;
                // ∂z'_i/∂y_j
                out_jac[(2 * i + 1) * dim + 2 * j] = dzp_dy[i * n + j];
            }
            // ∂y'_i/∂z_j = c_gy·Vy[i,j]·(1−dt1_j); ∂z'_i/∂z_j = (1−dt1_i)δ
            for j in 0..n {
                out_jac[(2 * i) * dim + 2 * j + 1] = c_gy * ry[j] * (S::one() - ws[j]);
                out_jac[(2 * i + 1) * dim + 2 * j + 1] = S::zero();
            }
            out_jac[(2 * i + 1) * dim + 2 * i + 1] = S::one() - dt1;
        }
    }

    /// Shared tail of the packed Block(2) kernels: block i is the 2×2 tile
    /// `[[∂y'_i/∂y_i, ∂y'_i/∂z_i], [∂z'_i/∂y_i, ∂z'_i/∂z_i]]`, each entry
    /// computed with the exact expression of the dense kernel at (i, i) —
    /// including the full `Σ_k Vy[i,k]·dzp_dy[k,i]` convolution — so the
    /// values are bitwise identical to the dense in-block entries at
    /// O(n) per unit (O(n²) per step) instead of the dense O(n³).
    #[inline]
    fn jacobian_block_from_ws(&self, s: &[S], out_f: &mut [S], out_jblk: &mut [S], ws: &mut [S]) {
        let n = self.n;
        let (v1, v2, vz, vy) = (self.v(0), self.v(1), self.v(2), self.v(3));
        // per-unit dzp_dy row coefficients into the block scratch at
        // ws[6n..8n] (the dense kernel's c1/c2, one pair per row k)
        let (head, scratch) = ws.split_at_mut(6 * n);
        let (c1s, c2s) = scratch.split_at_mut(n);
        for i in 0..n {
            let dt1 = head[i];
            let gz = head[2 * n + i];
            c1s[i] = (gz - s[2 * i + 1]) * dt1 * (S::one() - dt1);
            c2s[i] = dt1 * (S::one() - gz * gz);
        }
        for i in 0..n {
            let dt1 = head[i];
            let dt2 = head[n + i];
            let gy = head[4 * n + i];
            let yi = s[2 * i];
            out_f[2 * i] = (S::one() - dt2) * yi + dt2 * gy;
            out_f[2 * i + 1] = head[3 * n + i];

            let c_dt2 = (gy - yi) * dt2 * (S::one() - dt2);
            let c_gy = dt2 * (S::one() - gy * gy);
            let (r2, ry) = (&v2[i * n..(i + 1) * n], &vy[i * n..(i + 1) * n]);

            // ∂y'_i/∂y_i — same expression chain as the dense kernel at j=i
            let mut acc = c_dt2 * r2[i];
            let mut conv = S::zero();
            for k in 0..n {
                conv += ry[k] * (c1s[k] * v1[k * n + i] + c2s[k] * vz[k * n + i]);
            }
            acc += c_gy * conv;
            acc += S::one() - dt2;
            out_jblk[i * 4] = acc;
            // ∂y'_i/∂z_i
            out_jblk[i * 4 + 1] = c_gy * ry[i] * (S::one() - head[i]);
            // ∂z'_i/∂y_i = dzp_dy[i][i]
            out_jblk[i * 4 + 2] = c1s[i] * v1[i * n + i] + c2s[i] * vz[i * n + i];
            // ∂z'_i/∂z_i
            out_jblk[i * 4 + 3] = S::one() - dt1;
        }
    }
}

impl<S: Scalar> Cell<S> for Lem<S> {
    fn state_dim(&self) -> usize {
        2 * self.n
    }
    fn input_dim(&self) -> usize {
        self.m
    }
    fn ws_len(&self) -> usize {
        8 * self.n
    }

    /// The natural pairing: each unit's `(y_i, z_i)` 2-block.
    fn block_k(&self) -> Option<usize> {
        Some(2)
    }

    fn jacobian_structure(&self) -> JacobianStructure {
        // Dense through the V_k recurrences; Block(2) via BlockApprox
        // (exact when the V_k are diagonal).
        JacobianStructure::Dense
    }

    fn step(&self, s: &[S], x: &[S], out: &mut [S], ws: &mut [S]) {
        let n = self.n;
        self.forward_ws(s, x, None, ws);
        for i in 0..n {
            let dt2 = ws[n + i];
            out[2 * i] = (S::one() - dt2) * s[2 * i] + dt2 * ws[4 * n + i]; // y'
            out[2 * i + 1] = ws[3 * n + i]; // z'
        }
    }

    fn jacobian(&self, s: &[S], x: &[S], out_f: &mut [S], out_jac: &mut [S], ws: &mut [S]) {
        self.forward_ws(s, x, None, ws);
        self.jacobian_from_ws(s, out_f, out_jac, &ws[..5 * self.n]);
    }

    fn x_precompute_len(&self) -> usize {
        K * self.n
    }

    /// `out[t] = [W₁x+b₁, W₂x+b₂, W_zx+b_z, W_yx+b_y]` — the
    /// trajectory-invariant input projections, hoisted out of the Newton
    /// loop. Accumulation order matches [`Lem::branch`] bitwise.
    fn precompute_x(&self, xs: &[S], out: &mut [S]) {
        let n = self.n;
        let m = self.m;
        let t_len = xs.len() / m;
        debug_assert_eq!(out.len(), t_len * K * n);
        for t in 0..t_len {
            let x = &xs[t * m..(t + 1) * m];
            let o = &mut out[t * K * n..(t + 1) * K * n];
            for k in 0..K {
                let w = self.w(k);
                let b = self.b(k);
                for i in 0..n {
                    let mut a = b[i];
                    let roww = &w[i * m..(i + 1) * m];
                    for j in 0..m {
                        a += roww[j] * x[j];
                    }
                    o[k * n + i] = a;
                }
            }
        }
    }

    fn jacobian_pre(&self, s: &[S], pre: &[S], out_f: &mut [S], out_jac: &mut [S], ws: &mut [S]) {
        self.forward_ws(s, &[], Some(pre), ws);
        self.jacobian_from_ws(s, out_f, out_jac, &ws[..5 * self.n]);
    }

    fn jacobian_block(&self, s: &[S], x: &[S], out_f: &mut [S], out_jblk: &mut [S], ws: &mut [S]) {
        self.forward_ws(s, x, None, ws);
        self.jacobian_block_from_ws(s, out_f, out_jblk, ws);
    }

    fn jacobian_block_pre(
        &self,
        s: &[S],
        pre: &[S],
        out_f: &mut [S],
        out_jblk: &mut [S],
        ws: &mut [S],
    ) {
        self.forward_ws(s, &[], Some(pre), ws);
        self.jacobian_block_from_ws(s, out_f, out_jblk, ws);
    }

    /// Fused batched Block(2) FUNCEVAL kernel (the ROADMAP follow-up from
    /// the Block(k) PR): the batch axis is folded into the recurrent gate
    /// matmuls — every `V_k[i, :]` row is loaded once per stage and
    /// streamed across all B elements. Unlike the LSTM, LEM's y-branch
    /// consumes the WHOLE z' vector (`V_y · z'`) and the block Jacobian
    /// needs all units' `c1/c2` coefficients, so the gate values are
    /// staged in a `[B, 6n]` slab (allocated only when `B ≥ 2`, where it
    /// amortizes across the batch; `B = 1` takes the allocation-free
    /// per-element kernel on the caller's scratch). Per-element accumulation
    /// order is identical to [`Lem::branch`] / [`Lem::forward_ws`] /
    /// [`Lem::jacobian_block_from_ws`] (pre-computed base first, then the
    /// `V·q` j-loop; the conv's k-loop order), so the result is
    /// **bitwise** equal to the looped default — the driver's
    /// fused-vs-per-element dispatch never changes numerics.
    fn jacobian_pre_block_batch(
        &self,
        hs: &[S],
        pres: &[S],
        out_f: &mut [S],
        out_jblk: &mut [S],
        ws: &mut [S],
        batch: usize,
    ) {
        let n = self.n;
        let dim = 2 * n;
        let pl = K * n;
        let bl = dim * 2; // packed [n, 2, 2] per element
        debug_assert_eq!(hs.len(), batch * dim);
        debug_assert_eq!(pres.len(), batch * pl);
        debug_assert_eq!(out_f.len(), batch * dim);
        debug_assert_eq!(out_jblk.len(), batch * bl);
        // B = 1 (a worker owning a single sequence — the common shape when
        // B < threads): the per-element kernel on the caller's scratch is
        // the same math with no staging slab, keeping the per-timestep hot
        // path allocation-free; the [B, 7n] slab below is only paid when
        // it amortizes across ≥2 elements' matmuls.
        if batch == 1 {
            self.jacobian_block_pre(hs, pres, out_f, out_jblk, ws);
            return;
        }
        let _ = ws;
        let (v1, v2, vz, vy) = (self.v(0), self.v(1), self.v(2), self.v(3));
        // per-element staging planes: [dt1, dt2, zp, gy, c1s, c2s] (gz is
        // consumed locally in stage 1 and never staged)
        const PLANES: usize = 6;
        let mut slab = vec![S::zero(); batch * PLANES * n];

        // stage 1: the three y-carried branches, batch axis inside the row
        // loop (per-scalar chains: pre base, then the V·y j-loop in order)
        for i in 0..n {
            let (r1, r2, rz) = (
                &v1[i * n..(i + 1) * n],
                &v2[i * n..(i + 1) * n],
                &vz[i * n..(i + 1) * n],
            );
            for b in 0..batch {
                let s = &hs[b * dim..(b + 1) * dim];
                let pre = &pres[b * pl..(b + 1) * pl];
                let mut a1 = pre[i];
                let mut a2 = pre[n + i];
                let mut az = pre[2 * n + i];
                for j in 0..n {
                    let yj = s[2 * j];
                    a1 += r1[j] * yj;
                    a2 += r2[j] * yj;
                    az += rz[j] * yj;
                }
                let el = &mut slab[b * PLANES * n..(b + 1) * PLANES * n];
                let dt1 = sigmoid(a1);
                let gz = az.tanh();
                el[i] = dt1;
                el[n + i] = sigmoid(a2);
                // z' = (1 − dt1)·z + dt1·gz, z read interleaved (s[2i+1])
                el[2 * n + i] = (S::one() - dt1) * s[2 * i + 1] + dt1 * gz;
                // jacobian coefficients of the z' rows (dense kernel's c1/c2)
                el[4 * n + i] = (gz - s[2 * i + 1]) * dt1 * (S::one() - dt1);
                el[5 * n + i] = dt1 * (S::one() - gz * gz);
            }
        }
        // stage 2: the y-branch over the freshly-built z' carrier
        for i in 0..n {
            let ry = &vy[i * n..(i + 1) * n];
            for b in 0..batch {
                let pre = &pres[b * pl..(b + 1) * pl];
                let el = &slab[b * PLANES * n..(b + 1) * PLANES * n];
                let mut ay = pre[3 * n + i];
                for j in 0..n {
                    ay += ry[j] * el[2 * n + j];
                }
                slab[b * PLANES * n + 3 * n + i] = ay.tanh();
            }
        }
        // stage 3: outputs + packed 2×2 blocks (the dense kernel's exact
        // per-entry expressions, incl. the full Σ_k V_y·∂z'/∂y convolution)
        for i in 0..n {
            let (r2, ry) = (&v2[i * n..(i + 1) * n], &vy[i * n..(i + 1) * n]);
            for b in 0..batch {
                let s = &hs[b * dim..(b + 1) * dim];
                let el = &slab[b * PLANES * n..(b + 1) * PLANES * n];
                let dt1 = el[i];
                let dt2 = el[n + i];
                let gy = el[3 * n + i];
                let (c1s, c2s) = (&el[4 * n..5 * n], &el[5 * n..6 * n]);
                let yi = s[2 * i];
                out_f[b * dim + 2 * i] = (S::one() - dt2) * yi + dt2 * gy;
                out_f[b * dim + 2 * i + 1] = el[2 * n + i];

                let c_dt2 = (gy - yi) * dt2 * (S::one() - dt2);
                let c_gy = dt2 * (S::one() - gy * gy);
                let mut acc = c_dt2 * r2[i];
                let mut conv = S::zero();
                for k in 0..n {
                    conv += ry[k] * (c1s[k] * v1[k * n + i] + c2s[k] * vz[k * n + i]);
                }
                acc += c_gy * conv;
                acc += S::one() - dt2;
                let blk = &mut out_jblk[b * bl + i * 4..b * bl + (i + 1) * 4];
                blk[0] = acc; // ∂y'_i/∂y_i
                blk[1] = c_gy * ry[i] * (S::one() - dt1); // ∂y'_i/∂z_i
                blk[2] = c1s[i] * v1[i * n + i] + c2s[i] * vz[i * n + i]; // ∂z'_i/∂y_i
                blk[3] = S::one() - dt1; // ∂z'_i/∂z_i
            }
        }
    }

    fn flops_step(&self) -> u64 {
        let (n, m) = (self.n as u64, self.m as u64);
        2 * 4 * n * (n + m) + 16 * n
    }

    fn flops_jacobian(&self) -> u64 {
        let n = self.n as u64;
        // dominated by the V_y · ∂z'/∂y product: n³
        self.flops_step() + 2 * n * n * n + 8 * n * n
    }
}

impl<S: Scalar> CellGrad<S> for Lem<S> {
    fn num_params(&self) -> usize {
        self.p.len()
    }
    fn params(&self) -> &[S] {
        &self.p
    }
    fn params_mut(&mut self) -> &mut [S] {
        &mut self.p
    }

    fn vjp_step(
        &self,
        s: &[S],
        x: &[S],
        lambda: &[S],
        dh: &mut [S],
        mut dx: Option<&mut [S]>,
        dtheta: &mut [S],
        ws: &mut [S],
    ) {
        let n = self.n;
        let m = self.m;
        self.forward_ws(s, x, None, ws);
        let (work, tail) = ws.split_at(5 * n);
        let ybuf = &tail[..n];
        let zp: Vec<S> = work[3 * n..4 * n].to_vec();

        let (v1, v2, vz, vy) = (self.v(0), self.v(1), self.v(2), self.v(3));

        // λ components read interleaved: λ_y_i = lambda[2i], λ_z_i = lambda[2i+1]
        // --- y' branch ---
        // y' = (1−dt2) y + dt2·gy,   gy = tanh(W_y x + V_y z' + b_y)
        let mut da2 = vec![S::zero(); n]; // pre-act adjoint of dt2 branch
        let mut day = vec![S::zero(); n]; // pre-act adjoint of y branch (tanh arg)
        let mut dzp = vec![S::zero(); n]; // adjoint of z'
        for i in 0..n {
            let dt2 = work[n + i];
            let gy = work[4 * n + i];
            let lam_y = lambda[2 * i];
            dh[2 * i] += lam_y * (S::one() - dt2);
            da2[i] = lam_y * (gy - s[2 * i]) * dt2 * (S::one() - dt2);
            day[i] = lam_y * dt2 * (S::one() - gy * gy);
        }
        // dzp += V_yᵀ day ; dh(y part) += V_2ᵀ da2
        for i in 0..n {
            let (a2, ay) = (da2[i], day[i]);
            let (r2, ry) = (&v2[i * n..(i + 1) * n], &vy[i * n..(i + 1) * n]);
            for j in 0..n {
                dh[2 * j] += r2[j] * a2;
                dzp[j] += ry[j] * ay;
            }
        }
        // z' cotangent also flows directly from λ_z
        for i in 0..n {
            dzp[i] += lambda[2 * i + 1];
        }

        // --- z' branch ---
        // z' = (1−dt1) z + dt1·gz,   gz = tanh(W_z x + V_z y + b_z)
        let mut da1 = vec![S::zero(); n];
        let mut daz = vec![S::zero(); n];
        for i in 0..n {
            let dt1 = work[i];
            let gz = work[2 * n + i];
            dh[2 * i + 1] += dzp[i] * (S::one() - dt1);
            da1[i] = dzp[i] * (gz - s[2 * i + 1]) * dt1 * (S::one() - dt1);
            daz[i] = dzp[i] * dt1 * (S::one() - gz * gz);
        }
        for i in 0..n {
            let (a1, az) = (da1[i], daz[i]);
            let (r1, rz) = (&v1[i * n..(i + 1) * n], &vz[i * n..(i + 1) * n]);
            for j in 0..n {
                dh[2 * j] += r1[j] * a1 + rz[j] * az;
            }
        }

        // --- parameters and inputs ---
        // branch k uses carrier q_k ∈ {y, y, y, z'} and pre-act adjoint a_k.
        let adjoints = [&da1, &da2, &daz, &day];
        for k in 0..K {
            let a = adjoints[[0usize, 1, 2, 3][k]];
            // NOTE: branch order in params is [dt1, dt2, z, y] = [da1, da2, daz, day]
            let q: &[S] = if k == 3 { &zp } else { ybuf };
            let w = self.w(k);
            let (ow, ov, ob) = (self.off_w(k), self.off_v(k), self.off_b(k));
            for i in 0..n {
                let ai = a[i];
                if ai == S::zero() {
                    continue;
                }
                for j in 0..m {
                    dtheta[ow + i * m + j] += ai * x[j];
                }
                for j in 0..n {
                    dtheta[ov + i * n + j] += ai * q[j];
                }
                dtheta[ob + i] += ai;
                if let Some(dx) = dx.as_deref_mut() {
                    let roww = &w[i * m..(i + 1) * m];
                    for j in 0..m {
                        dx[j] += roww[j] * ai;
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cells::test_support::{check_jacobian, check_vjp};

    #[test]
    fn jacobian_matches_fd() {
        let mut rng = Rng::new(13);
        for &(n, m) in &[(1usize, 1usize), (2, 2), (4, 3)] {
            let cell: Lem<f64> = Lem::new(n, m, &mut rng);
            check_jacobian(&cell, 500 + n as u64, 1e-6);
        }
    }

    #[test]
    fn vjp_matches_fd() {
        let mut rng = Rng::new(14);
        let cell: Lem<f64> = Lem::new(3, 2, &mut rng);
        check_vjp(&cell, 600, 1e-6);
    }

    #[test]
    fn convex_combination_property() {
        // Both state branches are convex combinations with tanh-bounded
        // targets, so |s'|∞ ≤ max(|s|∞, 1).
        let mut rng = Rng::new(15);
        let cell: Lem<f64> = Lem::new(6, 3, &mut rng);
        let mut s = vec![0.0; 12];
        let mut x = vec![0.0; 3];
        let mut out = vec![0.0; 12];
        let mut ws = vec![0.0; cell.ws_len()];
        for _ in 0..100 {
            rng.fill_normal(&mut x, 1.0);
            cell.step(&s, &x, &mut out, &mut ws);
            std::mem::swap(&mut s, &mut out);
            assert!(s.iter().all(|v| v.abs() <= 1.0 + 1e-12));
        }
    }

    /// The packed Block(2) kernel must reproduce the dense Jacobian's
    /// in-block entries bitwise (and the same f), directly and through the
    /// precomputed-input path.
    #[test]
    fn block_kernel_matches_dense_blocks_bitwise() {
        let mut rng = Rng::new(19);
        for &(n, m) in &[(1usize, 1usize), (3, 2), (5, 3)] {
            let cell: Lem<f64> = Lem::new(n, m, &mut rng);
            let dim = 2 * n;
            let mut s = vec![0.0; dim];
            let mut x = vec![0.0; m];
            rng.fill_normal(&mut s, 0.7);
            rng.fill_normal(&mut x, 1.0);
            let mut ws = vec![0.0; cell.ws_len()];

            let mut f_d = vec![0.0; dim];
            let mut jac = vec![0.0; dim * dim];
            cell.jacobian(&s, &x, &mut f_d, &mut jac, &mut ws);

            let mut f_b = vec![0.0; dim];
            let mut jblk = vec![0.0; dim * 2];
            cell.jacobian_block(&s, &x, &mut f_b, &mut jblk, &mut ws);
            assert_eq!(f_d, f_b, "n={n}: block f");
            for i in 0..n {
                for r in 0..2 {
                    for c in 0..2 {
                        assert_eq!(
                            jblk[i * 4 + r * 2 + c],
                            jac[(2 * i + r) * dim + 2 * i + c],
                            "n={n} block {i} ({r},{c})"
                        );
                    }
                }
            }

            // precomputed-input path, bitwise equal to the direct one
            let pl = cell.x_precompute_len();
            let mut pre = vec![0.0; pl];
            cell.precompute_x(&x, &mut pre);
            let mut f_p = vec![0.0; dim];
            let mut jac_p = vec![0.0; dim * dim];
            cell.jacobian_pre(&s, &pre, &mut f_p, &mut jac_p, &mut ws);
            assert_eq!(f_p, f_d, "n={n}: jacobian_pre f");
            assert_eq!(jac_p, jac, "n={n}: jacobian_pre jac");
            let mut f_bp = vec![0.0; dim];
            let mut jblk_p = vec![0.0; dim * 2];
            cell.jacobian_block_pre(&s, &pre, &mut f_bp, &mut jblk_p, &mut ws);
            assert_eq!(f_bp, f_b, "n={n}: jacobian_block_pre f");
            assert_eq!(jblk_p, jblk, "n={n}: jacobian_block_pre blocks");
        }
    }

    /// With diagonal recurrent matrices V_k the dense Jacobian is exactly
    /// block-diagonal (the setting where the Block(2) path is exact).
    #[test]
    fn diagonal_recurrence_makes_jacobian_block_diagonal() {
        let (n, m) = (3usize, 2usize);
        let mut rng = Rng::new(29);
        let mut cell: Lem<f64> = Lem::new(n, m, &mut rng);
        let vbase = K * n * m;
        for k in 0..K {
            for i in 0..n {
                for j in 0..n {
                    if i != j {
                        cell.params_mut()[vbase + k * n * n + i * n + j] = 0.0;
                    }
                }
            }
        }
        let dim = 2 * n;
        let mut s = vec![0.0; dim];
        let mut x = vec![0.0; m];
        rng.fill_normal(&mut s, 0.7);
        rng.fill_normal(&mut x, 1.0);
        let mut ws = vec![0.0; cell.ws_len()];
        let mut f = vec![0.0; dim];
        let mut jac = vec![0.0; dim * dim];
        cell.jacobian(&s, &x, &mut f, &mut jac, &mut ws);
        for r in 0..dim {
            for c in 0..dim {
                if r / 2 != c / 2 {
                    assert_eq!(jac[r * dim + c], 0.0, "off-block ({r},{c}) nonzero");
                }
            }
        }
    }
}
