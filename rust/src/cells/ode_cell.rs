//! Trainable continuous-time cells: parametric vector fields wrapped as
//! [`Cell`]/[`CellGrad`] so `Model`/`TrainLoop` run Seq(RK4)-vs-DEER-ODE
//! as a pure A/B (paper §3.3/§4.2, the NeuralODE leg).
//!
//! An [`OdeField`] is an autonomous parametric vector field
//! `ẏ = f_θ(y)` with an analytic Jacobian `∂f/∂y` and parameter VJPs —
//! the continuous-time analogue of a [`CellGrad`]. Two heads ship:
//!
//! * [`MlpField`] — one-hidden-layer tanh MLP, the generic NeuralODE head.
//!   Implements the **exact** second-order pullback
//!   [`OdeField::vjp_jac_params`], so the DEER-ODE dual scan can account
//!   for the Jacobian's parameter dependence.
//! * [`HamiltonianField`] — `f = Ω∇H_θ` with a scalar MLP Hamiltonian
//!   (Greydanus et al. 2019), the structure-preserving head for the
//!   two-body experiment.
//!
//! [`OdeCell`] wraps a field plus a step size into a discrete
//! [`CellGrad`]: its `step` is the classical RK4 flow map over
//! `substeps` sub-intervals of `dt` (the Seq arm integrates the ODE
//! sequentially with BPTT-through-RK4 via the analytic RK4 adjoint in
//! [`CellGrad::vjp_step`]), while the DEER arms bypass the discrete step
//! entirely: [`Cell::ode_view`] exposes the underlying field, and the
//! executor/trainer dispatch the whole sequence to
//! [`crate::deer::deer_ode_batch`] / `deer_ode_backward_batch` on the
//! grid `t_i = i·dt`. Inputs do **not** enter the dynamics — the first
//! input frame is the initial condition (`h0`), which both arms consume
//! identically — so `input_dim() == state_dim()` and the cell is the
//! continuous drop-in for the twobody trajectory-fitting task.

use super::{init_uniform, Cell, CellGrad, JacobianStructure};
use crate::deer::ode::Interp;
use crate::util::rng::Rng;
use crate::util::scalar::Scalar;

/// An autonomous parametric vector field `ẏ = f_θ(y)` with analytic
/// Jacobian and parameter VJPs — the continuous-time [`CellGrad`].
///
/// Methods may allocate small scratch `Vec`s internally: fields are
/// evaluated per grid node outside the structured scan hot path, and the
/// allocation keeps the trait object-safe (`&dyn OdeField` is what
/// [`OdeView`] and the executor's `FieldSystem` adapter carry).
pub trait OdeField<S: Scalar>: Send + Sync {
    /// State dimension n.
    fn dim(&self) -> usize;
    /// Number of trainable parameters (flat layout).
    fn num_params(&self) -> usize;
    /// Flat parameter vector.
    fn params(&self) -> &[S];
    /// Mutable flat parameter vector.
    fn params_mut(&mut self) -> &mut [S];

    /// `out = f_θ(y)`.
    fn f(&self, y: &[S], out: &mut [S]);
    /// `out = ∂f/∂y` (row-major n×n).
    fn jac(&self, y: &[S], out: &mut [S]);

    /// Accumulate `dtheta += uᵀ ∂f/∂θ` (parameter leg only).
    ///
    /// This is the variant the DEER-ODE backward pass calls through the
    /// executor's `&self`-shared system adapter, which cannot offer a
    /// per-thread state-cotangent scratch buffer.
    fn vjp_params(&self, y: &[S], u: &[S], dtheta: &mut [S]);

    /// Accumulate the full pullback: `dy += uᵀ ∂f/∂y` and
    /// `dtheta += uᵀ ∂f/∂θ` (the RK4-adjoint leg of the Seq arm).
    fn vjp(&self, y: &[S], u: &[S], dy: &mut [S], dtheta: &mut [S]);

    /// Accumulate `dtheta += Σ_{c,c'} w[c,c'] ∂J[c,c']/∂θ` — the pullback
    /// through the Jacobian's own parameter dependence (`w` is a row-major
    /// n×n cotangent on `J`).
    ///
    /// Default: no-op. Dropping this term truncates the DEER-ODE dual at
    /// the same O(Δ²)-per-step order as the frozen-linearisation scan
    /// itself (for `z = f − Jy` the `∂J/∂y` contributions cancel at
    /// leading order because the linearisation is tangent), so the default
    /// is consistent; [`MlpField`] implements it exactly.
    fn vjp_jac_params(&self, y: &[S], w: &[S], dtheta: &mut [S]) {
        let _ = (y, w, dtheta);
    }

    /// Structure of `∂f/∂y` — drives the packed-kernel dispatch of
    /// [`crate::deer::deer_ode_batch`] exactly like
    /// [`Cell::jacobian_structure`] does for the discrete path.
    fn structure(&self) -> JacobianStructure {
        JacobianStructure::Dense
    }

    /// Packed diagonal of `∂f/∂y` (length n). Only meaningful when
    /// [`OdeField::structure`] is `Diagonal`.
    fn jac_diag(&self, y: &[S], out: &mut [S]) {
        let _ = (y, out);
        unimplemented!("field does not have a diagonal Jacobian")
    }
}

/// Borrowed view of a cell's continuous-time interior, exposed through
/// [`Cell::ode_view`]. `Some(view)` is the dispatch signal the trainer and
/// [`crate::coordinator::BatchExecutor`] key on to route a layer through
/// `deer_ode_batch` on the cell-step grid `t_i = i·dt`; `substeps` only
/// refines the Seq arm's RK4 flow inside one cell step.
#[derive(Clone, Copy)]
pub struct OdeView<'a, S: Scalar> {
    /// The parametric vector field.
    pub field: &'a dyn OdeField<S>,
    /// Grid spacing of one discrete cell step.
    pub dt: S,
    /// RK4 sub-intervals per cell step on the Seq arm (≥ 1).
    pub substeps: usize,
    /// DEER-ODE interpolation rule (paper App. A.5/Table 3).
    pub interp: Interp,
}

/// One-hidden-layer tanh MLP vector field: `f = W₂·tanh(W₁y + b₁) + b₂`.
///
/// Flat layout: `[W₁ (h×n row-major), b₁ (h), W₂ (n×h row-major), b₂ (n)]`.
#[derive(Debug, Clone)]
pub struct MlpField<S: Scalar> {
    n: usize,
    hidden: usize,
    params: Vec<S>,
}

impl<S: Scalar> MlpField<S> {
    /// New field with uniform(±1/√fan_in) initialisation per layer.
    pub fn new(n: usize, hidden: usize, rng: &mut Rng) -> Self {
        assert!(n > 0 && hidden > 0);
        let p = hidden * n + hidden + n * hidden + n;
        let mut params = vec![S::zero(); p];
        let (l1, l2) = params.split_at_mut(hidden * n + hidden);
        init_uniform(l1, n, rng);
        init_uniform(l2, hidden, rng);
        MlpField { n, hidden, params }
    }

    /// Hidden width.
    pub fn hidden(&self) -> usize {
        self.hidden
    }

    #[inline]
    fn offsets(&self) -> (usize, usize, usize) {
        let (n, h) = (self.n, self.hidden);
        (h * n, h * n + h, h * n + h + n * h) // (b1, w2, b2)
    }

    /// tanh pre-activations and activations: `(t = tanh(W₁y + b₁))`.
    fn hidden_act(&self, y: &[S]) -> Vec<S> {
        let (n, h) = (self.n, self.hidden);
        let (ob1, _, _) = self.offsets();
        let w1 = &self.params[..h * n];
        let b1 = &self.params[ob1..ob1 + h];
        let mut t = vec![S::zero(); h];
        for j in 0..h {
            let mut a = b1[j];
            for c in 0..n {
                a += w1[j * n + c] * y[c];
            }
            t[j] = a.tanh();
        }
        t
    }
}

impl<S: Scalar> OdeField<S> for MlpField<S> {
    fn dim(&self) -> usize {
        self.n
    }
    fn num_params(&self) -> usize {
        self.params.len()
    }
    fn params(&self) -> &[S] {
        &self.params
    }
    fn params_mut(&mut self) -> &mut [S] {
        &mut self.params
    }

    fn f(&self, y: &[S], out: &mut [S]) {
        let (n, h) = (self.n, self.hidden);
        let (_, ow2, ob2) = self.offsets();
        let t = self.hidden_act(y);
        let w2 = &self.params[ow2..ow2 + n * h];
        let b2 = &self.params[ob2..ob2 + n];
        for i in 0..n {
            let mut v = b2[i];
            for j in 0..h {
                v += w2[i * h + j] * t[j];
            }
            out[i] = v;
        }
    }

    fn jac(&self, y: &[S], out: &mut [S]) {
        let (n, h) = (self.n, self.hidden);
        let (_, ow2, _) = self.offsets();
        let t = self.hidden_act(y);
        let w1 = &self.params[..h * n];
        let w2 = &self.params[ow2..ow2 + n * h];
        // J = W₂ · diag(1 − t²) · W₁
        for i in 0..n {
            for c in 0..n {
                let mut v = S::zero();
                for j in 0..h {
                    let s = S::one() - t[j] * t[j];
                    v += w2[i * h + j] * s * w1[j * n + c];
                }
                out[i * n + c] = v;
            }
        }
    }

    fn vjp_params(&self, y: &[S], u: &[S], dtheta: &mut [S]) {
        let (n, h) = (self.n, self.hidden);
        let (ob1, ow2, ob2) = self.offsets();
        let t = self.hidden_act(y);
        let w2 = &self.params[ow2..ow2 + n * h];
        // db2 += u ; dW2[i,j] += u_i t_j ; v_j = s_j (W₂ᵀu)_j ;
        // db1 += v ; dW1[j,c] += v_j y_c
        for i in 0..n {
            dtheta[ob2 + i] += u[i];
            for j in 0..h {
                dtheta[ow2 + i * h + j] += u[i] * t[j];
            }
        }
        for j in 0..h {
            let mut wu = S::zero();
            for i in 0..n {
                wu += w2[i * h + j] * u[i];
            }
            let v = (S::one() - t[j] * t[j]) * wu;
            dtheta[ob1 + j] += v;
            for c in 0..n {
                dtheta[j * n + c] += v * y[c];
            }
        }
    }

    fn vjp(&self, y: &[S], u: &[S], dy: &mut [S], dtheta: &mut [S]) {
        let (n, h) = (self.n, self.hidden);
        let (ob1, ow2, ob2) = self.offsets();
        let t = self.hidden_act(y);
        let w1 = &self.params[..h * n];
        let w2 = &self.params[ow2..ow2 + n * h];
        for i in 0..n {
            dtheta[ob2 + i] += u[i];
            for j in 0..h {
                dtheta[ow2 + i * h + j] += u[i] * t[j];
            }
        }
        for j in 0..h {
            let mut wu = S::zero();
            for i in 0..n {
                wu += w2[i * h + j] * u[i];
            }
            let v = (S::one() - t[j] * t[j]) * wu;
            dtheta[ob1 + j] += v;
            for c in 0..n {
                dtheta[j * n + c] += v * y[c];
                dy[c] += v * w1[j * n + c];
            }
        }
    }

    fn vjp_jac_params(&self, y: &[S], w: &[S], dtheta: &mut [S]) {
        let (n, h) = (self.n, self.hidden);
        let (ob1, ow2, _) = self.offsets();
        let t = self.hidden_act(y);
        let w1 = &self.params[..h * n];
        let w2 = &self.params[ow2..ow2 + n * h];
        // J[i,c] = Σ_j W2[i,j]·s_j·W1[j,c] with s_j = 1 − t_j², and the
        // pre-activation a_j = (W1 y + b1)_j feeds s_j through s' = −2ts.
        //   r1[j,c] = Σ_i W2[i,j]·w[i,c]      (h×n)
        //   r2[i,j] = Σ_c w[i,c]·W1[j,c]      (n×h)
        //   q_j     = Σ_c r1[j,c]·W1[j,c]
        //   dW2[i,j] += s_j·r2[i,j]
        //   dW1[j,c] += s_j·r1[j,c] + (−2 t_j s_j)·y_c·q_j
        //   db1[j]   += (−2 t_j s_j)·q_j       (b2 does not enter J)
        let two = S::from_f64c(2.0);
        for j in 0..h {
            let s = S::one() - t[j] * t[j];
            let sp = -(two * t[j] * s);
            let mut q = S::zero();
            for c in 0..n {
                let mut r1 = S::zero();
                for i in 0..n {
                    r1 += w2[i * h + j] * w[i * n + c];
                }
                q += r1 * w1[j * n + c];
                dtheta[j * n + c] += s * r1;
            }
            for i in 0..n {
                let mut r2 = S::zero();
                for c in 0..n {
                    r2 += w[i * n + c] * w1[j * n + c];
                }
                dtheta[ow2 + i * h + j] += s * r2;
            }
            for c in 0..n {
                dtheta[j * n + c] += sp * y[c] * q;
            }
            dtheta[ob1 + j] += sp * q;
        }
    }
}

/// Hamiltonian vector field `f = Ω∇H_θ`, `H_θ = w₂ᵀ·tanh(W₁y + b₁)`,
/// `Ω = [[0, I], [−I, 0]]` — state is `[q (d), p (d)]`, n = 2d.
///
/// Flat layout: `[W₁ (h×n row-major), b₁ (h), w₂ (h)]`. Energy is
/// conserved along exact flows regardless of θ, which is what makes this
/// the right head for the two-body problem (Greydanus et al. 2019).
#[derive(Debug, Clone)]
pub struct HamiltonianField<S: Scalar> {
    d: usize,
    hidden: usize,
    params: Vec<S>,
}

impl<S: Scalar> HamiltonianField<S> {
    /// New field on n = 2·`d` states with `hidden` tanh units.
    pub fn new(d: usize, hidden: usize, rng: &mut Rng) -> Self {
        assert!(d > 0 && hidden > 0);
        let n = 2 * d;
        let p = hidden * n + hidden + hidden;
        let mut params = vec![S::zero(); p];
        let (l1, l2) = params.split_at_mut(hidden * n + hidden);
        init_uniform(l1, n, rng);
        init_uniform(l2, hidden, rng);
        HamiltonianField { d, hidden, params }
    }

    /// Scalar Hamiltonian `H_θ(y)` (energy readout for diagnostics).
    pub fn energy(&self, y: &[S]) -> S {
        let h = self.hidden;
        let ow2 = h * (2 * self.d) + h;
        let t = self.hidden_act(y);
        let w2 = &self.params[ow2..ow2 + h];
        let mut e = S::zero();
        for j in 0..h {
            e += w2[j] * t[j];
        }
        e
    }

    fn hidden_act(&self, y: &[S]) -> Vec<S> {
        let (n, h) = (2 * self.d, self.hidden);
        let w1 = &self.params[..h * n];
        let b1 = &self.params[h * n..h * n + h];
        let mut t = vec![S::zero(); h];
        for j in 0..h {
            let mut a = b1[j];
            for c in 0..n {
                a += w1[j * n + c] * y[c];
            }
            t[j] = a.tanh();
        }
        t
    }

    /// `g = ∇H` (length n).
    fn grad_h(&self, t: &[S]) -> Vec<S> {
        let (n, h) = (2 * self.d, self.hidden);
        let w1 = &self.params[..h * n];
        let w2 = &self.params[h * n + h..];
        let mut g = vec![S::zero(); n];
        for j in 0..h {
            let s = S::one() - t[j] * t[j];
            let sw = s * w2[j];
            for c in 0..n {
                g[c] += w1[j * n + c] * sw;
            }
        }
        g
    }
}

impl<S: Scalar> OdeField<S> for HamiltonianField<S> {
    fn dim(&self) -> usize {
        2 * self.d
    }
    fn num_params(&self) -> usize {
        self.params.len()
    }
    fn params(&self) -> &[S] {
        &self.params
    }
    fn params_mut(&mut self) -> &mut [S] {
        &mut self.params
    }

    fn f(&self, y: &[S], out: &mut [S]) {
        let d = self.d;
        let t = self.hidden_act(y);
        let g = self.grad_h(&t);
        for k in 0..d {
            out[k] = g[k + d];
            out[k + d] = -g[k];
        }
    }

    fn jac(&self, y: &[S], out: &mut [S]) {
        let (d, h) = (self.d, self.hidden);
        let n = 2 * d;
        let w1 = &self.params[..h * n];
        let w2 = &self.params[h * n + h..];
        let t = self.hidden_act(y);
        // Hess[c,c'] = Σ_j W1[j,c]·w2_j·(−2 t_j s_j)·W1[j,c']
        let mut hess = vec![S::zero(); n * n];
        let two = S::from_f64c(2.0);
        for j in 0..h {
            let s = S::one() - t[j] * t[j];
            let coef = -(two * t[j] * s) * w2[j];
            for c in 0..n {
                let wc = w1[j * n + c] * coef;
                for cc in 0..n {
                    hess[c * n + cc] += wc * w1[j * n + cc];
                }
            }
        }
        // J = Ω·Hess: row k<d = Hess row k+d; row k≥d = −Hess row k−d.
        for k in 0..d {
            for cc in 0..n {
                out[k * n + cc] = hess[(k + d) * n + cc];
                out[(k + d) * n + cc] = -hess[k * n + cc];
            }
        }
    }

    fn vjp_params(&self, y: &[S], u: &[S], dtheta: &mut [S]) {
        let mut dy_sink = vec![S::zero(); 2 * self.d];
        self.vjp(y, u, &mut dy_sink, dtheta);
    }

    fn vjp(&self, y: &[S], u: &[S], dy: &mut [S], dtheta: &mut [S]) {
        let (d, h) = (self.d, self.hidden);
        let n = 2 * d;
        let (ob1, ow2) = (h * n, h * n + h);
        let w1 = &self.params[..h * n];
        let w2 = &self.params[ow2..ow2 + h];
        let t = self.hidden_act(y);
        // v = Ωᵀu on the ∇H leg
        let mut v = vec![S::zero(); n];
        for c in 0..d {
            v[c] = -u[c + d];
            v[c + d] = u[c];
        }
        let two = S::from_f64c(2.0);
        for j in 0..h {
            let s = S::one() - t[j] * t[j];
            let sp = -(two * t[j] * s); // s'(a) through a_j
            let mut p = S::zero();
            for c in 0..n {
                p += w1[j * n + c] * v[c];
            }
            dtheta[ow2 + j] += s * p;
            dtheta[ob1 + j] += w2[j] * sp * p;
            let wsp = w2[j] * sp * p;
            for c in 0..n {
                dtheta[j * n + c] += w2[j] * (s * v[c] + sp * y[c] * p);
                dy[c] += w1[j * n + c] * wsp;
            }
        }
    }
}

/// A parametric vector field integrated as a discrete [`CellGrad`].
///
/// `step` is the RK4 flow map over `substeps` sub-intervals of `dt`
/// (input-free: the per-step `x` is ignored — the first input frame is
/// the trajectory's initial condition, consumed by the trainer before the
/// recurrence starts). [`Cell::jacobian`] chains the analytic per-stage
/// Jacobians through the RK4 tableau, and [`CellGrad::vjp_step`] is the
/// exact discrete RK4 adjoint, so the Seq arm is honest
/// BPTT-through-RK4. [`Cell::ode_view`] returns `Some`, which is what
/// flips the trainer/executor onto the fused `deer_ode_batch` path.
#[derive(Debug, Clone)]
pub struct OdeCell<S: Scalar, F: OdeField<S>> {
    field: F,
    dt: S,
    substeps: usize,
    interp: Interp,
}

impl<S: Scalar, F: OdeField<S>> OdeCell<S, F> {
    /// Wrap `field` with cell-step grid spacing `dt`, `substeps` RK4
    /// sub-intervals per step on the Seq arm, and the DEER-ODE `interp`.
    pub fn new(field: F, dt: f64, substeps: usize, interp: Interp) -> Self {
        assert!(dt > 0.0, "--dt must be > 0");
        assert!(substeps >= 1, "--substeps must be ≥ 1");
        OdeCell { field, dt: S::from_f64c(dt), substeps, interp }
    }

    /// The wrapped field.
    pub fn field(&self) -> &F {
        &self.field
    }

    /// Cell-step grid spacing.
    pub fn dt(&self) -> S {
        self.dt
    }

    /// One RK4 substep `y ← y + h/6·(k1 + 2k2 + 2k3 + k4)` in place.
    /// `ws` carries [k1 k2 k3 k4 ytmp] = 5n scratch.
    fn rk4_substep(&self, y: &mut [S], h: S, ws: &mut [S]) {
        let n = self.field.dim();
        let half = S::from_f64c(0.5);
        let sixth = S::from_f64c(1.0 / 6.0);
        let two = S::from_f64c(2.0);
        let (k1, rest) = ws.split_at_mut(n);
        let (k2, rest) = rest.split_at_mut(n);
        let (k3, rest) = rest.split_at_mut(n);
        let (k4, rest) = rest.split_at_mut(n);
        let ytmp = &mut rest[..n];
        self.field.f(y, k1);
        for i in 0..n {
            ytmp[i] = y[i] + half * h * k1[i];
        }
        self.field.f(ytmp, k2);
        for i in 0..n {
            ytmp[i] = y[i] + half * h * k2[i];
        }
        self.field.f(ytmp, k3);
        for i in 0..n {
            ytmp[i] = y[i] + h * k3[i];
        }
        self.field.f(ytmp, k4);
        let c = h * sixth;
        for i in 0..n {
            y[i] += c * (k1[i] + two * k2[i] + two * k3[i] + k4[i]);
        }
    }
}

/// `mat ← a·b` (n×n row-major).
fn matmul_into<S: Scalar>(a: &[S], b: &[S], out: &mut [S], n: usize) {
    for i in 0..n {
        for c in 0..n {
            let mut v = S::zero();
            for j in 0..n {
                v += a[i * n + j] * b[j * n + c];
            }
            out[i * n + c] = v;
        }
    }
}

impl<S: Scalar, F: OdeField<S>> Cell<S> for OdeCell<S, F> {
    fn state_dim(&self) -> usize {
        self.field.dim()
    }
    fn input_dim(&self) -> usize {
        self.field.dim()
    }
    fn ws_len(&self) -> usize {
        let n = self.field.dim();
        self.substeps * n + 5 * n * n + 10 * n
    }

    fn step(&self, h: &[S], x: &[S], out: &mut [S], ws: &mut [S]) {
        let _ = x; // autonomous flow: input only seeds h0 (trainer-side)
        let n = self.field.dim();
        let hs = self.dt / S::from_f64c(self.substeps as f64);
        out.copy_from_slice(&h[..n]);
        for _ in 0..self.substeps {
            self.rk4_substep(out, hs, ws);
        }
    }

    fn jacobian(&self, h: &[S], x: &[S], out_f: &mut [S], out_jac: &mut [S], ws: &mut [S]) {
        let _ = x;
        let n = self.field.dim();
        let nn = n * n;
        let hs = self.dt / S::from_f64c(self.substeps as f64);
        let half = S::from_f64c(0.5);
        let sixth = S::from_f64c(1.0 / 6.0);
        let two = S::from_f64c(2.0);
        // vectors: y k1 k2 k3 ytmp (5n) — k4 folds into the update;
        // matrices: jt b a asum jtot (5n²)
        let (vecs, mats) = ws.split_at_mut(self.substeps * n + 10 * n);
        let (y, rest) = vecs.split_at_mut(n);
        let (k1, rest) = rest.split_at_mut(n);
        let (k2, rest) = rest.split_at_mut(n);
        let (k3, rest) = rest.split_at_mut(n);
        let ytmp = &mut rest[..n];
        let (jt, rest_m) = mats.split_at_mut(nn);
        let (bm, rest_m) = rest_m.split_at_mut(nn);
        let (am, rest_m) = rest_m.split_at_mut(nn);
        let (asum, rest_m) = rest_m.split_at_mut(nn);
        let jtot = &mut rest_m[..nn];

        y.copy_from_slice(&h[..n]);
        // jtot = I
        for v in jtot.iter_mut() {
            *v = S::zero();
        }
        for i in 0..n {
            jtot[i * n + i] = S::one();
        }
        for _ in 0..self.substeps {
            // stage 1: A1 = J(y)
            self.field.f(y, k1);
            self.field.jac(y, am);
            asum.copy_from_slice(am);
            // stage 2: A2 = J(y + h/2 k1)·(I + h/2 A1)
            for i in 0..n {
                ytmp[i] = y[i] + half * hs * k1[i];
            }
            self.field.f(ytmp, k2);
            self.field.jac(ytmp, jt);
            for i in 0..n {
                for c in 0..n {
                    bm[i * n + c] =
                        half * hs * am[i * n + c] + if i == c { S::one() } else { S::zero() };
                }
            }
            matmul_into(jt, bm, am, n);
            for i in 0..nn {
                asum[i] += two * am[i];
            }
            // stage 3: A3 = J(y + h/2 k2)·(I + h/2 A2)
            for i in 0..n {
                ytmp[i] = y[i] + half * hs * k2[i];
            }
            self.field.f(ytmp, k3);
            self.field.jac(ytmp, jt);
            for i in 0..n {
                for c in 0..n {
                    bm[i * n + c] =
                        half * hs * am[i * n + c] + if i == c { S::one() } else { S::zero() };
                }
            }
            matmul_into(jt, bm, am, n);
            for i in 0..nn {
                asum[i] += two * am[i];
            }
            // stage 4: A4 = J(y + h k3)·(I + h A3)
            for i in 0..n {
                ytmp[i] = y[i] + hs * k3[i];
            }
            self.field.jac(ytmp, jt);
            for i in 0..n {
                for c in 0..n {
                    bm[i * n + c] = hs * am[i * n + c] + if i == c { S::one() } else { S::zero() };
                }
            }
            matmul_into(jt, bm, am, n);
            for i in 0..nn {
                asum[i] += am[i];
            }
            // state update needs k4 = f(y + h k3); ytmp still holds that
            // node and jt's first n slots are free to carry k4
            let k4 = jt;
            self.field.f(ytmp, &mut k4[..n]);
            let c6 = hs * sixth;
            for i in 0..n {
                y[i] += c6 * (k1[i] + two * k2[i] + two * k3[i] + k4[i]);
            }
            // Jsub = I + h/6·asum ; jtot ← Jsub·jtot
            for i in 0..n {
                for c in 0..n {
                    bm[i * n + c] =
                        c6 * asum[i * n + c] + if i == c { S::one() } else { S::zero() };
                }
            }
            matmul_into(bm, jtot, am, n);
            jtot.copy_from_slice(am);
        }
        out_f.copy_from_slice(y);
        out_jac.copy_from_slice(jtot);
    }

    fn jacobian_structure(&self) -> JacobianStructure {
        // The RK4 flow-map Jacobian I + Δ·J + … is dense even for
        // structured fields; the structured DEER-ODE path reads the
        // FIELD's structure through ode_view(), not this.
        JacobianStructure::Dense
    }

    fn ode_view(&self) -> Option<OdeView<'_, S>> {
        Some(OdeView {
            field: &self.field,
            dt: self.dt,
            substeps: self.substeps,
            interp: self.interp,
        })
    }

    fn flops_step(&self) -> u64 {
        // 4 field evals per substep; MLP-ish fields are ~4·n·h ≈ 8n² flops
        let n = self.field.dim() as u64;
        self.substeps as u64 * 4 * 8 * n * n
    }

    fn flops_jacobian(&self) -> u64 {
        let n = self.field.dim() as u64;
        self.flops_step() + self.substeps as u64 * (4 * 8 * n * n + 4 * 2 * n * n * n)
    }
}

impl<S: Scalar, F: OdeField<S>> CellGrad<S> for OdeCell<S, F> {
    fn num_params(&self) -> usize {
        self.field.num_params()
    }
    fn params(&self) -> &[S] {
        self.field.params()
    }
    fn params_mut(&mut self) -> &mut [S] {
        self.field.params_mut()
    }

    fn vjp_step(
        &self,
        h: &[S],
        x: &[S],
        lambda: &[S],
        dh: &mut [S],
        dx: Option<&mut [S]>,
        dtheta: &mut [S],
        ws: &mut [S],
    ) {
        let _ = (x, dx); // autonomous: no input cotangent
        let n = self.field.dim();
        let hs = self.dt / S::from_f64c(self.substeps as f64);
        let half = S::from_f64c(0.5);
        let sixth = S::from_f64c(1.0 / 6.0);
        let two = S::from_f64c(2.0);
        let c6 = hs * sixth;
        // forward: store each substep's initial state
        let (ys, rest) = ws.split_at_mut(self.substeps * n);
        let (lam, rest) = rest.split_at_mut(n);
        let (k1, rest) = rest.split_at_mut(n);
        let (k2, rest) = rest.split_at_mut(n);
        let (k3, rest) = rest.split_at_mut(n);
        let (y2, rest) = rest.split_at_mut(n);
        let (y3, rest) = rest.split_at_mut(n);
        let (y4, rest) = rest.split_at_mut(n);
        let (u, rest) = rest.split_at_mut(n);
        let (g, rest) = rest.split_at_mut(n);
        let ycur = &mut rest[..n];

        ycur.copy_from_slice(&h[..n]);
        for s in 0..self.substeps {
            ys[s * n..(s + 1) * n].copy_from_slice(ycur);
            // inline rk4_substep (scratch slices are already split)
            self.field.f(ycur, k1);
            for i in 0..n {
                y2[i] = ycur[i] + half * hs * k1[i];
            }
            self.field.f(y2, k2);
            for i in 0..n {
                y3[i] = ycur[i] + half * hs * k2[i];
            }
            self.field.f(y3, k3);
            for i in 0..n {
                y4[i] = ycur[i] + hs * k3[i];
            }
            self.field.f(y4, u); // k4 in u
            for i in 0..n {
                ycur[i] += c6 * (k1[i] + two * k2[i] + two * k3[i] + u[i]);
            }
        }

        lam.copy_from_slice(&lambda[..n]);
        for s in (0..self.substeps).rev() {
            let y1 = &ys[s * n..(s + 1) * n];
            // recompute stage nodes
            self.field.f(y1, k1);
            for i in 0..n {
                y2[i] = y1[i] + half * hs * k1[i];
            }
            self.field.f(y2, k2);
            for i in 0..n {
                y3[i] = y1[i] + half * hs * k2[i];
            }
            self.field.f(y3, k3);
            for i in 0..n {
                y4[i] = y1[i] + hs * k3[i];
            }
            // reverse through the tableau; g accumulates λ_new − λ
            for v in g.iter_mut() {
                *v = S::zero();
            }
            // dk4 = c6·λ → pull through f at y4
            for i in 0..n {
                u[i] = c6 * lam[i];
            }
            let mut g4 = vec![S::zero(); n];
            self.field.vjp(y4, u, &mut g4, dtheta);
            // dk3 = 2c6·λ + h·g4
            for i in 0..n {
                u[i] = two * c6 * lam[i] + hs * g4[i];
            }
            let mut g3 = vec![S::zero(); n];
            self.field.vjp(y3, u, &mut g3, dtheta);
            // dk2 = 2c6·λ + h/2·g3
            for i in 0..n {
                u[i] = two * c6 * lam[i] + half * hs * g3[i];
            }
            let mut g2 = vec![S::zero(); n];
            self.field.vjp(y2, u, &mut g2, dtheta);
            // dk1 = c6·λ + h/2·g2
            for i in 0..n {
                u[i] = c6 * lam[i] + half * hs * g2[i];
            }
            let mut g1 = vec![S::zero(); n];
            self.field.vjp(y1, u, &mut g1, dtheta);
            for i in 0..n {
                g[i] = g1[i] + g2[i] + g3[i] + g4[i];
            }
            for i in 0..n {
                lam[i] += g[i];
            }
        }
        for i in 0..n {
            dh[i] += lam[i];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cells::fd_jacobian;
    use crate::linalg::max_abs_diff;

    fn mlp(n: usize, h: usize, seed: u64) -> MlpField<f64> {
        let mut rng = Rng::new(seed);
        MlpField::new(n, h, &mut rng)
    }

    fn hnn(d: usize, h: usize, seed: u64) -> HamiltonianField<f64> {
        let mut rng = Rng::new(seed);
        HamiltonianField::new(d, h, &mut rng)
    }

    fn fd_field_jac(field: &dyn OdeField<f64>, y: &[f64]) -> Vec<f64> {
        let n = field.dim();
        let eps = 1e-6;
        let mut jac = vec![0.0; n * n];
        let mut yp = y.to_vec();
        let mut ym = y.to_vec();
        let (mut fp, mut fm) = (vec![0.0; n], vec![0.0; n]);
        for j in 0..n {
            yp[j] += eps;
            ym[j] -= eps;
            field.f(&yp, &mut fp);
            field.f(&ym, &mut fm);
            for i in 0..n {
                jac[i * n + j] = (fp[i] - fm[i]) / (2.0 * eps);
            }
            yp[j] = y[j];
            ym[j] = y[j];
        }
        jac
    }

    #[test]
    fn mlp_field_jacobian_matches_fd() {
        let field = mlp(4, 8, 11);
        let mut rng = Rng::new(5);
        let mut y = vec![0.0; 4];
        rng.fill_normal(&mut y, 0.9);
        let mut jac = vec![0.0; 16];
        field.jac(&y, &mut jac);
        let fd = fd_field_jac(&field, &y);
        assert!(max_abs_diff(&jac, &fd) < 1e-7);
    }

    #[test]
    fn hamiltonian_field_jacobian_matches_fd_and_is_symplectic() {
        let field = hnn(2, 10, 3);
        let mut rng = Rng::new(9);
        let mut y = vec![0.0; 4];
        rng.fill_normal(&mut y, 0.8);
        let n = 4;
        let mut jac = vec![0.0; n * n];
        field.jac(&y, &mut jac);
        let fd = fd_field_jac(&field, &y);
        assert!(max_abs_diff(&jac, &fd) < 1e-7);
        // J = Ω·Hess with symmetric Hess ⇒ tr(J) = 0 (divergence-free flow)
        let tr: f64 = (0..n).map(|i| jac[i * n + i]).sum();
        assert!(tr.abs() < 1e-12, "Hamiltonian flow must be divergence-free, tr={tr}");
    }

    #[test]
    fn field_vjp_matches_fd() {
        for field in [mlp(3, 6, 21), mlp(5, 4, 22)] {
            let n = field.dim();
            let p = field.num_params();
            let mut rng = Rng::new(31);
            let mut y = vec![0.0; n];
            let mut u = vec![0.0; n];
            rng.fill_normal(&mut y, 0.8);
            rng.fill_normal(&mut u, 1.0);
            let mut dy = vec![0.0; n];
            let mut dth = vec![0.0; p];
            field.vjp(&y, &u, &mut dy, &mut dth);
            // θ-only variant must agree on the parameter leg
            let mut dth2 = vec![0.0; p];
            field.vjp_params(&y, &u, &mut dth2);
            assert!(max_abs_diff(&dth, &dth2) < 1e-14);

            let eps = 1e-6;
            let dot = |a: &[f64], b: &[f64]| a.iter().zip(b).map(|(x, z)| x * z).sum::<f64>();
            let eval = |field: &MlpField<f64>, y: &[f64]| {
                let mut out = vec![0.0; n];
                field.f(y, &mut out);
                out
            };
            for j in 0..n {
                let mut yp = y.clone();
                let mut ym = y.clone();
                yp[j] += eps;
                ym[j] -= eps;
                let want = (dot(&u, &eval(&field, &yp)) - dot(&u, &eval(&field, &ym))) / (2.0 * eps);
                assert!((dy[j] - want).abs() < 1e-7, "dy[{j}]");
            }
            for j in 0..p {
                let mut fp = field.clone();
                let mut fm = field.clone();
                fp.params_mut()[j] += eps;
                fm.params_mut()[j] -= eps;
                let want = (dot(&u, &eval(&fp, &y)) - dot(&u, &eval(&fm, &y))) / (2.0 * eps);
                assert!((dth[j] - want).abs() < 1e-7, "dth[{j}]");
            }
        }
    }

    #[test]
    fn hamiltonian_vjp_matches_fd() {
        let field = hnn(2, 6, 41);
        let n = field.dim();
        let p = field.num_params();
        let mut rng = Rng::new(43);
        let mut y = vec![0.0; n];
        let mut u = vec![0.0; n];
        rng.fill_normal(&mut y, 0.8);
        rng.fill_normal(&mut u, 1.0);
        let mut dy = vec![0.0; n];
        let mut dth = vec![0.0; p];
        field.vjp(&y, &u, &mut dy, &mut dth);
        let eps = 1e-6;
        let dot = |a: &[f64], b: &[f64]| a.iter().zip(b).map(|(x, z)| x * z).sum::<f64>();
        let eval = |field: &HamiltonianField<f64>, y: &[f64]| {
            let mut out = vec![0.0; n];
            field.f(y, &mut out);
            out
        };
        for j in 0..n {
            let mut yp = y.clone();
            let mut ym = y.clone();
            yp[j] += eps;
            ym[j] -= eps;
            let want = (dot(&u, &eval(&field, &yp)) - dot(&u, &eval(&field, &ym))) / (2.0 * eps);
            assert!((dy[j] - want).abs() < 1e-7, "dy[{j}]");
        }
        for j in 0..p {
            let mut fp = field.clone();
            let mut fm = field.clone();
            fp.params_mut()[j] += eps;
            fm.params_mut()[j] -= eps;
            let want = (dot(&u, &eval(&fp, &y)) - dot(&u, &eval(&fm, &y))) / (2.0 * eps);
            assert!((dth[j] - want).abs() < 1e-7, "dth[{j}]");
        }
    }

    #[test]
    fn mlp_vjp_jac_params_matches_fd() {
        let field = mlp(3, 5, 51);
        let n = field.dim();
        let p = field.num_params();
        let mut rng = Rng::new(53);
        let mut y = vec![0.0; n];
        let mut w = vec![0.0; n * n];
        rng.fill_normal(&mut y, 0.8);
        rng.fill_normal(&mut w, 1.0);
        let mut dth = vec![0.0; p];
        field.vjp_jac_params(&y, &w, &mut dth);
        let eps = 1e-6;
        let obj = |field: &MlpField<f64>| {
            let mut jac = vec![0.0; n * n];
            field.jac(&y, &mut jac);
            jac.iter().zip(&w).map(|(a, b)| a * b).sum::<f64>()
        };
        for j in 0..p {
            let mut fp = field.clone();
            let mut fm = field.clone();
            fp.params_mut()[j] += eps;
            fm.params_mut()[j] -= eps;
            let want = (obj(&fp) - obj(&fm)) / (2.0 * eps);
            assert!((dth[j] - want).abs() < 2e-6, "djac_th[{j}]: {} vs {want}", dth[j]);
        }
    }

    #[test]
    fn ode_cell_jacobian_matches_fd() {
        for substeps in [1usize, 3] {
            let cell: OdeCell<f64, MlpField<f64>> =
                OdeCell::new(mlp(4, 8, 61), 0.05, substeps, Interp::Midpoint);
            let n = cell.state_dim();
            let mut rng = Rng::new(63);
            let mut h = vec![0.0; n];
            let x = vec![0.0; n];
            rng.fill_normal(&mut h, 0.8);
            let mut f = vec![0.0; n];
            let mut jac = vec![0.0; n * n];
            let mut ws = vec![0.0; cell.ws_len()];
            cell.jacobian(&h, &x, &mut f, &mut jac, &mut ws);
            // fused f must equal step
            let mut f2 = vec![0.0; n];
            cell.step(&h, &x, &mut f2, &mut ws);
            assert!(max_abs_diff(&f, &f2) < 1e-14, "fused f vs step");
            let fd = fd_jacobian(&cell, &h, &x, 1e-6);
            assert!(
                max_abs_diff(&jac, &fd) < 1e-7,
                "substeps={substeps}: {}",
                max_abs_diff(&jac, &fd)
            );
        }
    }

    #[test]
    fn ode_cell_vjp_matches_fd() {
        for substeps in [1usize, 2] {
            let cell: OdeCell<f64, MlpField<f64>> =
                OdeCell::new(mlp(3, 6, 71), 0.04, substeps, Interp::Midpoint);
            let n = cell.state_dim();
            let p = cell.num_params();
            let mut rng = Rng::new(73);
            let mut h = vec![0.0; n];
            let mut lam = vec![0.0; n];
            rng.fill_normal(&mut h, 0.7);
            rng.fill_normal(&mut lam, 1.0);
            let x = vec![0.0; n];
            let mut dh = vec![0.0; n];
            let mut dth = vec![0.0; p];
            let mut ws = vec![0.0; cell.ws_len()];
            cell.vjp_step(&h, &x, &lam, &mut dh, None, &mut dth, &mut ws);

            let eps = 1e-6;
            let dot = |a: &[f64], b: &[f64]| a.iter().zip(b).map(|(x, z)| x * z).sum::<f64>();
            let eval = |cell: &OdeCell<f64, MlpField<f64>>, h: &[f64]| {
                let mut out = vec![0.0; n];
                let mut ws = vec![0.0; cell.ws_len()];
                cell.step(h, &[0.0; 3], &mut out, &mut ws);
                out
            };
            for j in 0..n {
                let mut hp = h.clone();
                let mut hm = h.clone();
                hp[j] += eps;
                hm[j] -= eps;
                let want =
                    (dot(&lam, &eval(&cell, &hp)) - dot(&lam, &eval(&cell, &hm))) / (2.0 * eps);
                assert!((dh[j] - want).abs() < 1e-7, "dh[{j}] substeps={substeps}");
            }
            for j in 0..p {
                let mut cp = cell.clone();
                let mut cm = cell.clone();
                cp.params_mut()[j] += eps;
                cm.params_mut()[j] -= eps;
                let want =
                    (dot(&lam, &eval(&cp, &h)) - dot(&lam, &eval(&cm, &h))) / (2.0 * eps);
                assert!((dth[j] - want).abs() < 1e-7, "dth[{j}] substeps={substeps}");
            }
        }
    }

    #[test]
    fn ode_view_exposes_field() {
        let cell: OdeCell<f64, HamiltonianField<f64>> =
            OdeCell::new(hnn(2, 6, 81), 0.01, 2, Interp::Left);
        let view = cell.ode_view().expect("OdeCell must expose an ode_view");
        assert_eq!(view.field.dim(), 4);
        assert_eq!(view.substeps, 2);
        assert_eq!(view.interp, Interp::Left);
        assert!((view.dt - 0.01).abs() < 1e-15);
        // a discrete cell reports none
        let mut rng = Rng::new(1);
        let gru: crate::cells::Gru<f64> = crate::cells::Gru::new(3, 2, &mut rng);
        assert!(gru.ode_view().is_none());
    }
}
