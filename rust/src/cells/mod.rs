//! Non-linear recurrent cells with analytic Jacobians and parameter VJPs.
//!
//! DEER (paper eq. 5) requires the per-step state Jacobian
//! `G_i = −∂f/∂h (h_{i−1}, x_i)` explicitly. JAX obtains it with `jacfwd`;
//! here each cell implements its Jacobian *analytically* — the same values,
//! verified against central finite differences in the tests, and against the
//! JAX implementation through the AOT artifacts.
//!
//! Cells implemented: [`Gru`] (the paper's main benchmark subject, §4.1/4.3),
//! [`Lstm`], [`Lem`] (Rusch et al. 2021; Table 1 and Fig. 8), [`Elman`]
//! (simplest test vehicle), and [`IndRnn`] (Li et al. 2018 — element-wise
//! recurrence, hence a **natively diagonal** state Jacobian). [`DiagGru`]
//! and [`DiagLstm`] are the diagonal-recurrence (ParaRNN-style) gated
//! variants: same gate math as [`Gru`]/[`Lstm`] but with `diag(u)`
//! recurrent weights, so their Jacobians are *natively* `Diagonal` /
//! `Block(2)` and Full mode rides the packed O(n)/O(n·k²) scan kernels as
//! exact Newton. All are generic over f32/f64 ([`Scalar`]).
//!
//! # Jacobian structure
//!
//! Each cell reports a [`JacobianStructure`]: `Dense` cells emit full
//! row-major n×n Jacobians; `Diagonal` cells additionally implement
//! [`Cell::jacobian_diag`], emitting only the n diagonal entries;
//! `Block { k }` covers block-diagonal Jacobians packed as `[n/k, k, k]`
//! contiguous k×k blocks. The DEER driver dispatches on the structure to
//! pick the O(n) diagonal kernels in [`crate::scan::diag`] or the
//! O((n/k)·k³) block kernels in [`crate::scan::block`] over the O(n³)
//! dense ones — see [`crate::deer::JacobianMode`] for the quasi-DEER modes
//! (`DiagonalApprox` / `BlockApprox`) that force the structured paths on
//! dense cells by approximation.
//!
//! **Block pairing**: [`Lstm`] and [`Lem`] report a natural `Block(2)`
//! pairing through [`Cell::block_k`]. Their state is stored **interleaved**
//! — `[h_0, c_0, h_1, c_1, …]` / `[y_0, z_0, …]` — so each unit's coupled
//! pair occupies one contiguous 2×2 block, and the packed kernels
//! ([`Cell::jacobian_block`] / [`Cell::jacobian_block_pre`] /
//! [`Cell::jacobian_pre_block_batch`]) emit `[T, n/2, 2, 2]` block slabs
//! instead of `[T, n, n]` dense ones, with the gate math shared through
//! [`Cell::precompute_x`]. The emitted block entries are bitwise identical
//! to the corresponding entries of the dense [`Cell::jacobian`]: when the
//! recurrent weight matrices are diagonal (the ParaRNN setting) the dense
//! Jacobian *is* block-diagonal and the Block(2) path is exact Newton; for
//! general dense recurrences it is the `BlockApprox` quasi mode (same
//! fixed point, linear rate — strictly better informed than the diagonal
//! approximation).
//!
//! Conventions:
//! * state `h` has length `state_dim()`; input `x` has `input_dim()`.
//! * All methods take a caller-provided scratch slice of `ws_len()` elements
//!   so the Newton hot loop allocates nothing.
//! * `vjp_step` *accumulates* (`+=`) into `dh`, `dx` and `dtheta`.
//! * Batched variants (`step_batch` / `jacobian_batch` /
//!   `jacobian_diag_batch` and the precomputed-input `jacobian_pre_batch`
//!   / `jacobian_diag_pre_batch`) evaluate B independent elements packed
//!   as `[B, n]` / `[B, m]` slabs — the cell-level leg of the end-to-end
//!   `[B, T, n]` layout. Defaults loop over the batch; cells may override
//!   to fuse the batch axis into the gate matmuls. The `*_pre_batch`
//!   kernels are the ones DEER's FUNCEVAL phase dispatches to (input
//!   projections are hoisted out of the Newton loop), so they carry the
//!   hot-path fusion; overrides must stay bitwise equal to the looped
//!   defaults. GRU/IndRNN fuse their dense/diagonal kernels; LSTM/LEM fuse
//!   the packed-block `jacobian_pre_block_batch` (the Block(2) hot path).
//! * `vjp_step`'s `dx` cotangent (implemented by every cell) is the
//!   inter-layer leg of stacked models: layer `l`'s input cotangents are
//!   layer `l − 1`'s output cotangents in the stacked backward chain.

pub mod diag_gru;
pub mod diag_lstm;
pub mod dyn_cell;
pub mod elman;
pub mod gru;
pub mod indrnn;
pub mod lem;
pub mod lstm;
pub mod ode_cell;

pub use diag_gru::DiagGru;
pub use diag_lstm::DiagLstm;
pub use dyn_cell::DynCell;
pub use elman::Elman;
pub use gru::Gru;
pub use indrnn::IndRnn;
pub use lem::Lem;
pub use lstm::Lstm;
pub use ode_cell::{HamiltonianField, MlpField, OdeCell, OdeField, OdeView};

use crate::util::rng::Rng;
use crate::util::scalar::Scalar;

/// Structure of a cell's per-step state Jacobian `∂f/∂h`.
///
/// Drives kernel dispatch in the DEER driver: `Diagonal` unlocks the O(n)
/// compose/apply scan kernels (packed n-entry Jacobians), `Block { k }` the
/// O((n/k)·k³) block-diagonal kernels in [`crate::scan::block`], and `Dense`
/// uses the general O(n³)-compose path of the paper's §3.5 cost model.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum JacobianStructure {
    /// Full row-major n×n Jacobian per step.
    #[default]
    Dense,
    /// Jacobian is diagonal; packed as n entries per step.
    Diagonal,
    /// Jacobian is block-diagonal with `n/k` contiguous k×k blocks along
    /// the state (`n % k == 0`); packed as `[n/k, k, k]` row-major blocks
    /// per step (`n·k` elements). Block `b` couples state components
    /// `b·k .. (b+1)·k` only — the ParaRNN-style structure of cells whose
    /// units carry a small tuple of coupled scalars (LSTM's `(h_i, c_i)`,
    /// LEM's `(y_i, z_i)` in the interleaved layout).
    Block {
        /// Block edge length (2 for the LSTM/LEM pairings).
        k: usize,
    },
}

impl JacobianStructure {
    /// Packed elements one per-step Jacobian occupies.
    pub fn jac_len(self, n: usize) -> usize {
        match self {
            JacobianStructure::Dense => n * n,
            JacobianStructure::Diagonal => n,
            JacobianStructure::Block { k } => {
                debug_assert!(k > 0 && n % k == 0, "state dim {n} not divisible by block {k}");
                (n / k) * k * k
            }
        }
    }

    /// Short label for bench/JSON metadata (`dense` | `diagonal` | `block2`).
    pub fn label(self) -> String {
        match self {
            JacobianStructure::Dense => "dense".to_string(),
            JacobianStructure::Diagonal => "diagonal".to_string(),
            JacobianStructure::Block { k } => format!("block{k}"),
        }
    }
}

/// A discrete-time non-linear recurrence `h' = f(h, x, θ)`.
pub trait Cell<S: Scalar>: Send + Sync {
    /// Dimension of the recurrent state vector.
    fn state_dim(&self) -> usize;
    /// Dimension of the per-step input vector.
    fn input_dim(&self) -> usize;
    /// Scratch length required by `step` / `jacobian`.
    fn ws_len(&self) -> usize;

    /// `out = f(h, x)`.
    fn step(&self, h: &[S], x: &[S], out: &mut [S], ws: &mut [S]);

    /// `out_f = f(h, x)` and `out_jac = ∂f/∂h` (row-major n×n), fused so the
    /// shared gate activations are computed once (this fusion is one of the
    /// §Perf optimizations; see EXPERIMENTS.md).
    fn jacobian(&self, h: &[S], x: &[S], out_f: &mut [S], out_jac: &mut [S], ws: &mut [S]);

    /// Structure of `∂f/∂h`. Cells returning
    /// [`JacobianStructure::Diagonal`] must implement
    /// [`Cell::jacobian_diag`] (and, if they support input precomputation,
    /// [`Cell::jacobian_diag_pre`]).
    fn jacobian_structure(&self) -> JacobianStructure {
        JacobianStructure::Dense
    }

    /// Natural block size `k` of the cell's state pairing, if it has one.
    ///
    /// Cells whose state packs small per-unit tuples contiguously (LSTM's
    /// `(h_i, c_i)`, LEM's `(y_i, z_i)`) report `Some(2)` here and implement
    /// the packed block kernels [`Cell::jacobian_block`] (plus
    /// [`Cell::jacobian_block_pre`] when they support input precomputation).
    /// [`crate::deer::JacobianMode::BlockApprox`] dispatches to those
    /// kernels; dense cells without a natural pairing return `None` and get
    /// the generic dense-evaluate/extract-blocks fallback. A cell whose
    /// [`Cell::jacobian_structure`] is `Block { k }` must return `Some(k)`.
    fn block_k(&self) -> Option<usize> {
        None
    }

    /// Like [`Cell::jacobian`] but emitting only the **packed k×k diagonal
    /// blocks** of `∂f/∂h` (`out_jblk` has `state_dim()·k` elements laid out
    /// `[n/k, k, k]`, `k = block_k().unwrap()`). The emitted values must be
    /// **bitwise** identical to the corresponding entries of the dense
    /// [`Cell::jacobian`] — the DEER driver treats the two as views of the
    /// same evaluation, and the Block-vs-Dense equivalence tests pin it.
    fn jacobian_block(&self, h: &[S], x: &[S], out_f: &mut [S], out_jblk: &mut [S], ws: &mut [S]) {
        let _ = (h, x, out_f, out_jblk, ws);
        unimplemented!("cell does not have packed block-Jacobian kernels")
    }

    /// [`Cell::jacobian_block`] from precomputed input projections (the
    /// gate math shared through [`Cell::precompute_x`], like the GRU/IndRNN
    /// fused kernels).
    fn jacobian_block_pre(
        &self,
        h: &[S],
        pre: &[S],
        out_f: &mut [S],
        out_jblk: &mut [S],
        ws: &mut [S],
    ) {
        let _ = (h, pre, out_f, out_jblk, ws);
        unimplemented!("cell does not have packed block-Jacobian kernels")
    }

    /// Batched [`Cell::jacobian_block`]: `out_jblk = [B, n·k]` packed
    /// blocks. Default loops over the batch.
    fn jacobian_block_batch(
        &self,
        hs: &[S],
        xs: &[S],
        out_f: &mut [S],
        out_jblk: &mut [S],
        ws: &mut [S],
        batch: usize,
    ) {
        let n = self.state_dim();
        let m = self.input_dim();
        let bl = n * self.block_k().expect("cell has no packed block kernels");
        debug_assert_eq!(out_f.len(), batch * n);
        debug_assert_eq!(out_jblk.len(), batch * bl);
        for s in 0..batch {
            self.jacobian_block(
                &hs[s * n..(s + 1) * n],
                &xs[s * m..(s + 1) * m],
                &mut out_f[s * n..(s + 1) * n],
                &mut out_jblk[s * bl..(s + 1) * bl],
                ws,
            );
        }
    }

    /// Batched [`Cell::jacobian_block_pre`] (packed-block variant): the
    /// fused FUNCEVAL kernel of the block path, same bitwise contract as
    /// [`Cell::jacobian_pre_batch`]. Default loops over the batch.
    fn jacobian_pre_block_batch(
        &self,
        hs: &[S],
        pres: &[S],
        out_f: &mut [S],
        out_jblk: &mut [S],
        ws: &mut [S],
        batch: usize,
    ) {
        let n = self.state_dim();
        let pl = self.x_precompute_len();
        let bl = n * self.block_k().expect("cell has no packed block kernels");
        debug_assert_eq!(out_f.len(), batch * n);
        debug_assert_eq!(out_jblk.len(), batch * bl);
        for s in 0..batch {
            self.jacobian_block_pre(
                &hs[s * n..(s + 1) * n],
                &pres[s * pl..(s + 1) * pl],
                &mut out_f[s * n..(s + 1) * n],
                &mut out_jblk[s * bl..(s + 1) * bl],
                ws,
            );
        }
    }

    /// Batched [`Cell::step`] over B independent (state, input) pairs packed
    /// as contiguous `[B, n]` / `[B, m]` slabs: `out[s] = f(hs[s], xs[s])`.
    ///
    /// The default implementation loops over the batch reusing one scratch
    /// buffer; cells with wide gate matmuls can override it to fuse the
    /// batch dimension into the inner products. This is the cell-level
    /// contract of the end-to-end `[B, T, n]` execution layout (see
    /// [`crate::scan`] and [`crate::deer::newton::deer_rnn_batch`]).
    fn step_batch(&self, hs: &[S], xs: &[S], out: &mut [S], ws: &mut [S], batch: usize) {
        let n = self.state_dim();
        let m = self.input_dim();
        debug_assert_eq!(hs.len(), batch * n);
        debug_assert_eq!(xs.len(), batch * m);
        debug_assert_eq!(out.len(), batch * n);
        for (s, o) in out.chunks_mut(n).enumerate().take(batch) {
            self.step(&hs[s * n..(s + 1) * n], &xs[s * m..(s + 1) * m], o, ws);
        }
    }

    /// Batched [`Cell::jacobian`]: `out_f = [B, n]`, `out_jac = [B, n·n]`
    /// row-major per element. Default loops over the batch.
    fn jacobian_batch(
        &self,
        hs: &[S],
        xs: &[S],
        out_f: &mut [S],
        out_jac: &mut [S],
        ws: &mut [S],
        batch: usize,
    ) {
        let n = self.state_dim();
        let m = self.input_dim();
        debug_assert_eq!(out_f.len(), batch * n);
        debug_assert_eq!(out_jac.len(), batch * n * n);
        for s in 0..batch {
            self.jacobian(
                &hs[s * n..(s + 1) * n],
                &xs[s * m..(s + 1) * m],
                &mut out_f[s * n..(s + 1) * n],
                &mut out_jac[s * n * n..(s + 1) * n * n],
                ws,
            );
        }
    }

    /// Batched [`Cell::jacobian_diag`] (packed-diagonal variant):
    /// `out_jdiag = [B, n]`. Only meaningful for `Diagonal` cells.
    fn jacobian_diag_batch(
        &self,
        hs: &[S],
        xs: &[S],
        out_f: &mut [S],
        out_jdiag: &mut [S],
        ws: &mut [S],
        batch: usize,
    ) {
        let n = self.state_dim();
        let m = self.input_dim();
        debug_assert_eq!(out_f.len(), batch * n);
        debug_assert_eq!(out_jdiag.len(), batch * n);
        for s in 0..batch {
            self.jacobian_diag(
                &hs[s * n..(s + 1) * n],
                &xs[s * m..(s + 1) * m],
                &mut out_f[s * n..(s + 1) * n],
                &mut out_jdiag[s * n..(s + 1) * n],
                ws,
            );
        }
    }

    /// Batched [`Cell::jacobian_pre`]: `hs = [B, n]`, `pres = [B,
    /// x_precompute_len()]`, `out_f = [B, n]`, `out_jac = [B, n·n]`.
    ///
    /// This is the kernel the DEER FUNCEVAL phase calls on its fused
    /// batched fast path (see `crate::deer::newton`): the driver gathers
    /// the active sequences' `h_{i−1}` rows and precomputed input
    /// projections for one timestep and evaluates them in one call, so an
    /// override can fold the batch axis into the recurrent gate matmuls.
    /// Overrides must keep the per-element accumulation order of
    /// [`Cell::jacobian_pre`] **bitwise** intact — the driver dispatches
    /// between this kernel and the per-element path on pool shape, and
    /// that dispatch must never change results. Default loops over the
    /// batch.
    fn jacobian_pre_batch(
        &self,
        hs: &[S],
        pres: &[S],
        out_f: &mut [S],
        out_jac: &mut [S],
        ws: &mut [S],
        batch: usize,
    ) {
        let n = self.state_dim();
        let pl = self.x_precompute_len();
        debug_assert_eq!(out_f.len(), batch * n);
        debug_assert_eq!(out_jac.len(), batch * n * n);
        for s in 0..batch {
            self.jacobian_pre(
                &hs[s * n..(s + 1) * n],
                &pres[s * pl..(s + 1) * pl],
                &mut out_f[s * n..(s + 1) * n],
                &mut out_jac[s * n * n..(s + 1) * n * n],
                ws,
            );
        }
    }

    /// Batched [`Cell::jacobian_diag_pre`] (packed-diagonal variant):
    /// `out_jdiag = [B, n]` — the fused FUNCEVAL kernel of the natively
    /// diagonal path, same bitwise contract as
    /// [`Cell::jacobian_pre_batch`]. Default loops over the batch.
    fn jacobian_diag_pre_batch(
        &self,
        hs: &[S],
        pres: &[S],
        out_f: &mut [S],
        out_jdiag: &mut [S],
        ws: &mut [S],
        batch: usize,
    ) {
        let n = self.state_dim();
        let pl = self.x_precompute_len();
        debug_assert_eq!(out_f.len(), batch * n);
        debug_assert_eq!(out_jdiag.len(), batch * n);
        for s in 0..batch {
            self.jacobian_diag_pre(
                &hs[s * n..(s + 1) * n],
                &pres[s * pl..(s + 1) * pl],
                &mut out_f[s * n..(s + 1) * n],
                &mut out_jdiag[s * n..(s + 1) * n],
                ws,
            );
        }
    }

    /// Like [`Cell::jacobian`] but emitting the **packed diagonal** of
    /// `∂f/∂h` (`out_jdiag` has length n). Only meaningful when
    /// [`Cell::jacobian_structure`] is `Diagonal`.
    fn jacobian_diag(&self, h: &[S], x: &[S], out_f: &mut [S], out_jdiag: &mut [S], ws: &mut [S]) {
        let _ = (h, x, out_f, out_jdiag, ws);
        unimplemented!("cell does not have a diagonal Jacobian")
    }

    /// [`Cell::jacobian_diag`] from precomputed input projections.
    fn jacobian_diag_pre(
        &self,
        h: &[S],
        pre: &[S],
        out_f: &mut [S],
        out_jdiag: &mut [S],
        ws: &mut [S],
    ) {
        let _ = (h, pre, out_f, out_jdiag, ws);
        unimplemented!("cell does not have a diagonal Jacobian")
    }

    /// Per-step length of the input-precomputation buffer (0 = unsupported).
    ///
    /// §Perf optimization: a cell's input projections (`W_i·x + b`) do not
    /// depend on the trajectory guess, so DEER can compute them **once per
    /// evaluation** instead of once per Newton iteration. Cells that support
    /// this return the per-step buffer length here and implement
    /// [`Cell::precompute_x`] + [`Cell::jacobian_pre`].
    fn x_precompute_len(&self) -> usize {
        0
    }

    /// Fill `out` (length `T · x_precompute_len()`) with per-step input
    /// projections for the whole sequence.
    fn precompute_x(&self, _xs: &[S], _out: &mut [S]) {
        unimplemented!("cell does not support input precomputation")
    }

    /// Like [`Cell::jacobian`] but reading the step's precomputed input
    /// projections instead of recomputing `W_i·x`.
    fn jacobian_pre(&self, h: &[S], pre: &[S], out_f: &mut [S], out_jac: &mut [S], ws: &mut [S]) {
        let _ = (h, pre, out_f, out_jac, ws);
        unimplemented!("cell does not support input precomputation")
    }

    /// Continuous-time interior, if this cell is an ODE flow map.
    ///
    /// Discrete cells return `None` (the default). [`OdeCell`] returns
    /// `Some` — the trainer and `BatchExecutor` key on it to bypass the
    /// per-step recurrence and solve the whole sequence with
    /// [`crate::deer::deer_ode_batch`] on the grid `t_i = i·dt`.
    fn ode_view(&self) -> Option<ode_cell::OdeView<'_, S>> {
        None
    }

    /// Approximate FLOPs of one `step` (used by the accelerator cost model).
    fn flops_step(&self) -> u64 {
        let n = self.state_dim() as u64;
        let m = self.input_dim() as u64;
        2 * n * (n + m) * 3
    }

    /// Approximate FLOPs of one fused `jacobian` call.
    fn flops_jacobian(&self) -> u64 {
        let n = self.state_dim() as u64;
        self.flops_step() + 4 * n * n
    }
}

/// Cells that additionally expose parameters and an analytic VJP, enabling
/// BPTT (sequential baseline) and the DEER backward pass (paper eq. 7).
pub trait CellGrad<S: Scalar>: Cell<S> {
    /// Number of trainable parameters (flat layout).
    fn num_params(&self) -> usize;
    /// Flat parameter vector.
    fn params(&self) -> &[S];
    /// Mutable flat parameter vector.
    fn params_mut(&mut self) -> &mut [S];

    /// Overwrite the cell's parameters from a flat vector (the optimizer →
    /// cell leg of the native training loop: updates computed on the flat
    /// layout round-trip through the same `params()` ordering).
    fn load_params(&mut self, src: &[S]) {
        let dst = self.params_mut();
        assert_eq!(src.len(), dst.len(), "flat parameter length");
        dst.copy_from_slice(src);
    }

    /// Given the cotangent `lambda = ∂L/∂h'` at one step, accumulate
    /// `dh += λᵀ ∂f/∂h`, `dx += λᵀ ∂f/∂x` (if requested) and
    /// `dtheta += λᵀ ∂f/∂θ`.
    fn vjp_step(
        &self,
        h: &[S],
        x: &[S],
        lambda: &[S],
        dh: &mut [S],
        dx: Option<&mut [S]>,
        dtheta: &mut [S],
        ws: &mut [S],
    );
}

/// Uniform(-1/√n, 1/√n) initialisation — the flax.linen default the paper's
/// benchmarks use on untrained cells.
pub fn init_uniform<S: Scalar>(params: &mut [S], fan_in: usize, rng: &mut Rng) {
    let bound = 1.0 / (fan_in.max(1) as f64).sqrt();
    rng.fill_uniform(params, -bound, bound);
}

/// σ(x) with care at extremes.
#[inline]
pub fn sigmoid<S: Scalar>(x: S) -> S {
    S::one() / (S::one() + (-x).exp())
}

/// Central-difference Jacobian (test helper) — O(n²) calls to `step`.
pub fn fd_jacobian<S: Scalar, C: Cell<S>>(cell: &C, h: &[S], x: &[S], eps: f64) -> Vec<S> {
    let n = cell.state_dim();
    let mut jac = vec![S::zero(); n * n];
    let mut hp = h.to_vec();
    let mut hm = h.to_vec();
    let mut fp = vec![S::zero(); n];
    let mut fm = vec![S::zero(); n];
    let mut ws = vec![S::zero(); cell.ws_len()];
    let e = S::from_f64c(eps);
    for j in 0..n {
        hp[j] = h[j] + e;
        hm[j] = h[j] - e;
        cell.step(&hp, x, &mut fp, &mut ws);
        cell.step(&hm, x, &mut fm, &mut ws);
        for i in 0..n {
            jac[i * n + j] = (fp[i] - fm[i]) / (e + e);
        }
        hp[j] = h[j];
        hm[j] = h[j];
    }
    jac
}

#[cfg(test)]
pub(crate) mod test_support {
    use super::*;

    /// Shared check: analytic Jacobian vs central differences.
    pub fn check_jacobian<C: Cell<f64>>(cell: &C, seed: u64, tol: f64) {
        let n = cell.state_dim();
        let m = cell.input_dim();
        let mut rng = Rng::new(seed);
        let mut h = vec![0.0; n];
        let mut x = vec![0.0; m];
        rng.fill_normal(&mut h, 0.8);
        rng.fill_normal(&mut x, 1.0);
        let mut f = vec![0.0; n];
        let mut jac = vec![0.0; n * n];
        let mut ws = vec![0.0; cell.ws_len()];
        cell.jacobian(&h, &x, &mut f, &mut jac, &mut ws);
        // f from jacobian() must equal step()
        let mut f2 = vec![0.0; n];
        cell.step(&h, &x, &mut f2, &mut ws);
        for (a, b) in f.iter().zip(f2.iter()) {
            assert!((a - b).abs() < 1e-14, "fused f mismatch: {a} vs {b}");
        }
        let fd = fd_jacobian(cell, &h, &x, 1e-6);
        for i in 0..n * n {
            assert!(
                (jac[i] - fd[i]).abs() < tol,
                "jac[{i}]: analytic {} vs fd {}",
                jac[i],
                fd[i]
            );
        }
    }

    /// Shared check: analytic VJP vs finite-difference directional derivatives
    /// for state, input and parameters.
    pub fn check_vjp<C: CellGrad<f64> + Clone>(cell: &C, seed: u64, tol: f64) {
        let n = cell.state_dim();
        let m = cell.input_dim();
        let p = cell.num_params();
        let mut rng = Rng::new(seed);
        let mut h = vec![0.0; n];
        let mut x = vec![0.0; m];
        let mut lam = vec![0.0; n];
        rng.fill_normal(&mut h, 0.7);
        rng.fill_normal(&mut x, 1.0);
        rng.fill_normal(&mut lam, 1.0);

        let mut dh = vec![0.0; n];
        let mut dx = vec![0.0; m];
        let mut dth = vec![0.0; p];
        let mut ws = vec![0.0; cell.ws_len()];
        cell.vjp_step(&h, &x, &lam, &mut dh, Some(&mut dx), &mut dth, &mut ws);

        let eps = 1e-6;
        let eval = |cell: &C, h: &[f64], x: &[f64]| -> Vec<f64> {
            let mut out = vec![0.0; n];
            let mut ws = vec![0.0; cell.ws_len()];
            cell.step(h, x, &mut out, &mut ws);
            out
        };
        let dot = |a: &[f64], b: &[f64]| a.iter().zip(b).map(|(p, q)| p * q).sum::<f64>();

        // state direction
        for j in 0..n {
            let mut hp = h.clone();
            let mut hm = h.clone();
            hp[j] += eps;
            hm[j] -= eps;
            let want = (dot(&lam, &eval(cell, &hp, &x)) - dot(&lam, &eval(cell, &hm, &x))) / (2.0 * eps);
            assert!((dh[j] - want).abs() < tol, "dh[{j}]: {} vs {want}", dh[j]);
        }
        // input direction
        for j in 0..m {
            let mut xp = x.clone();
            let mut xm = x.clone();
            xp[j] += eps;
            xm[j] -= eps;
            let want = (dot(&lam, &eval(cell, &h, &xp)) - dot(&lam, &eval(cell, &h, &xm))) / (2.0 * eps);
            assert!((dx[j] - want).abs() < tol, "dx[{j}]: {} vs {want}", dx[j]);
        }
        // a random subset of parameter directions (p can be large)
        let mut idx_rng = Rng::new(seed ^ 0xabcdef);
        for _ in 0..p.min(24) {
            let j = idx_rng.below(p);
            let mut cp = cell.clone();
            let mut cm = cell.clone();
            cp.params_mut()[j] += eps;
            cm.params_mut()[j] -= eps;
            let want = (dot(&lam, &eval(&cp, &h, &x)) - dot(&lam, &eval(&cm, &h, &x))) / (2.0 * eps);
            assert!(
                (dth[j] - want).abs() < tol,
                "dtheta[{j}]: {} vs {want}",
                dth[j]
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sigmoid_basics() {
        assert!((sigmoid(0.0f64) - 0.5).abs() < 1e-15);
        assert!(sigmoid(30.0f64) > 0.999999);
        assert!(sigmoid(-30.0f64) < 1e-6);
    }

    #[test]
    fn batched_step_and_jacobian_match_looped() {
        use crate::cells::{Gru, IndRnn};
        let mut rng = Rng::new(77);
        let (n, m, batch) = (3usize, 2usize, 4usize);
        let gru: Gru<f64> = Gru::new(n, m, &mut rng);
        let mut hs = vec![0.0; batch * n];
        let mut xs = vec![0.0; batch * m];
        rng.fill_normal(&mut hs, 0.7);
        rng.fill_normal(&mut xs, 1.0);
        let mut ws = vec![0.0; gru.ws_len()];

        let mut f_b = vec![0.0; batch * n];
        gru.step_batch(&hs, &xs, &mut f_b, &mut ws, batch);
        let mut jf_b = vec![0.0; batch * n];
        let mut jac_b = vec![0.0; batch * n * n];
        gru.jacobian_batch(&hs, &xs, &mut jf_b, &mut jac_b, &mut ws, batch);
        for s in 0..batch {
            let mut f = vec![0.0; n];
            gru.step(&hs[s * n..(s + 1) * n], &xs[s * m..(s + 1) * m], &mut f, &mut ws);
            for j in 0..n {
                assert_eq!(f[j], f_b[s * n + j], "step_batch seq {s}");
                assert_eq!(f[j], jf_b[s * n + j], "jacobian_batch f seq {s}");
            }
            let mut jac = vec![0.0; n * n];
            gru.jacobian(&hs[s * n..(s + 1) * n], &xs[s * m..(s + 1) * m], &mut f, &mut jac, &mut ws);
            for j in 0..n * n {
                assert_eq!(jac[j], jac_b[s * n * n + j], "jacobian_batch seq {s}");
            }
        }

        // packed-diagonal batched variant on a natively diagonal cell
        let ind: IndRnn<f64> = IndRnn::new(n, m, &mut rng);
        let mut iws = vec![0.0; ind.ws_len()];
        let mut df_b = vec![0.0; batch * n];
        let mut jd_b = vec![0.0; batch * n];
        ind.jacobian_diag_batch(&hs, &xs, &mut df_b, &mut jd_b, &mut iws, batch);
        for s in 0..batch {
            let mut f = vec![0.0; n];
            let mut jd = vec![0.0; n];
            ind.jacobian_diag(&hs[s * n..(s + 1) * n], &xs[s * m..(s + 1) * m], &mut f, &mut jd, &mut iws);
            for j in 0..n {
                assert_eq!(f[j], df_b[s * n + j]);
                assert_eq!(jd[j], jd_b[s * n + j]);
            }
        }
    }

    #[test]
    fn block_structure_packing() {
        let b2 = JacobianStructure::Block { k: 2 };
        assert_eq!(b2.jac_len(8), 8 * 2, "n/k blocks of k² = n·k packed elements");
        assert_eq!(JacobianStructure::Block { k: 4 }.jac_len(8), 8 * 4);
        assert_eq!(b2.label(), "block2");
        assert_eq!(JacobianStructure::Dense.label(), "dense");
        assert_eq!(JacobianStructure::Diagonal.label(), "diagonal");
        // k = n degenerates to dense, k = 1 to diagonal, in element count
        assert_eq!(JacobianStructure::Block { k: 1 }.jac_len(6), 6);
        assert_eq!(JacobianStructure::Block { k: 6 }.jac_len(6), 36);
    }

    #[test]
    fn init_within_bounds() {
        let mut p = vec![0.0f64; 1000];
        let mut rng = Rng::new(0);
        init_uniform(&mut p, 16, &mut rng);
        let b = 0.25;
        assert!(p.iter().all(|v| v.abs() <= b));
        assert!(p.iter().any(|v| v.abs() > b * 0.5));
    }
}
