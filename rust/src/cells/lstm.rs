//! LSTM (Hochreiter & Schmidhuber, 1997). The DEER framework treats the
//! packed state `s = [h, c]` (dimension 2n) as the recurrent vector, so its
//! Jacobian is the full 2n×2n block matrix
//!
//! ```text
//! ∂[h',c']/∂[h,c] = [ ∂h'/∂h  ∂h'/∂c ]
//!                   [ ∂c'/∂h  ∂c'/∂c ]
//! ```
//!
//! Equations:
//! ```text
//! i = σ(W_i x + U_i h + b_i)      f = σ(W_f x + U_f h + b_f)
//! g = tanh(W_g x + U_g h + b_g)   o = σ(W_o x + U_o h + b_o)
//! c' = f ⊙ c + i ⊙ g              h' = o ⊙ tanh(c')
//! ```

use super::{init_uniform, sigmoid, Cell, CellGrad};
use crate::util::rng::Rng;
use crate::util::scalar::Scalar;

/// LSTM cell with `n` hidden units and `m` inputs; `state_dim() = 2n`
/// (packed `[h, c]`).
///
/// Parameter layout: `[W_i, W_f, W_g, W_o] (4·n·m)`,
/// `[U_i, U_f, U_g, U_o] (4·n·n)`, `[b_i, b_f, b_g, b_o] (4·n)`.
#[derive(Debug, Clone)]
pub struct Lstm<S> {
    n: usize,
    m: usize,
    p: Vec<S>,
}

const GATES: usize = 4; // i, f, g, o

impl<S: Scalar> Lstm<S> {
    pub fn new(n: usize, m: usize, rng: &mut Rng) -> Self {
        let mut p = vec![S::zero(); GATES * (n * m + n * n + n)];
        init_uniform(&mut p, n, rng);
        Lstm { n, m, p }
    }

    pub fn from_params(n: usize, m: usize, p: Vec<S>) -> Self {
        assert_eq!(p.len(), GATES * (n * m + n * n + n));
        Lstm { n, m, p }
    }

    fn w(&self, k: usize) -> &[S] {
        let (n, m) = (self.n, self.m);
        &self.p[k * n * m..(k + 1) * n * m]
    }
    fn u(&self, k: usize) -> &[S] {
        let (n, m) = (self.n, self.m);
        let base = GATES * n * m;
        &self.p[base + k * n * n..base + (k + 1) * n * n]
    }
    fn b(&self, k: usize) -> &[S] {
        let (n, m) = (self.n, self.m);
        let base = GATES * (n * m + n * n);
        &self.p[base + k * n..base + (k + 1) * n]
    }
    fn off_w(&self, k: usize) -> usize {
        k * self.n * self.m
    }
    fn off_u(&self, k: usize) -> usize {
        GATES * self.n * self.m + k * self.n * self.n
    }
    fn off_b(&self, k: usize) -> usize {
        GATES * (self.n * self.m + self.n * self.n) + k * self.n
    }

    /// Gate activations into ws: [i, f, g, o, tanh(c'), c'] each length n.
    #[inline]
    fn gates(&self, s: &[S], x: &[S], ws: &mut [S]) {
        let n = self.n;
        let m = self.m;
        let h = &s[..n];
        let c = &s[n..2 * n];
        for k in 0..GATES {
            let w = self.w(k);
            let u = self.u(k);
            let b = self.b(k);
            for i in 0..n {
                let mut a = b[i];
                let roww = &w[i * m..(i + 1) * m];
                for j in 0..m {
                    a += roww[j] * x[j];
                }
                let rowu = &u[i * n..(i + 1) * n];
                for j in 0..n {
                    a += rowu[j] * h[j];
                }
                ws[k * n + i] = if k == 2 { a.tanh() } else { sigmoid(a) };
            }
        }
        for i in 0..n {
            let cp = ws[n + i] * c[i] + ws[i] * ws[2 * n + i]; // f·c + i·g
            ws[5 * n + i] = cp;
            ws[4 * n + i] = cp.tanh();
        }
    }
}

impl<S: Scalar> Cell<S> for Lstm<S> {
    fn state_dim(&self) -> usize {
        2 * self.n
    }
    fn input_dim(&self) -> usize {
        self.m
    }
    fn ws_len(&self) -> usize {
        6 * self.n
    }

    fn step(&self, s: &[S], x: &[S], out: &mut [S], ws: &mut [S]) {
        let n = self.n;
        self.gates(s, x, ws);
        for i in 0..n {
            out[i] = ws[3 * n + i] * ws[4 * n + i]; // h' = o·tanh(c')
            out[n + i] = ws[5 * n + i]; // c'
        }
    }

    fn jacobian(&self, s: &[S], x: &[S], out_f: &mut [S], out_jac: &mut [S], ws: &mut [S]) {
        let n = self.n;
        let dim = 2 * n;
        self.gates(s, x, ws);
        let c = &s[n..2 * n];
        let (u_i, u_f, u_g, u_o) = (self.u(0), self.u(1), self.u(2), self.u(3));
        for v in out_jac.iter_mut() {
            *v = S::zero();
        }
        for i in 0..n {
            let ig = ws[i];
            let fg = ws[n + i];
            let gg = ws[2 * n + i];
            let og = ws[3 * n + i];
            let tc = ws[4 * n + i];
            let cp = ws[5 * n + i];
            out_f[i] = og * tc;
            out_f[n + i] = cp;

            let di = ig * (S::one() - ig);
            let df = fg * (S::one() - fg);
            let dg = S::one() - gg * gg;
            let do_ = og * (S::one() - og);
            let dtc = S::one() - tc * tc;

            let (rui, ruf, rug, ruo) = (
                &u_i[i * n..(i + 1) * n],
                &u_f[i * n..(i + 1) * n],
                &u_g[i * n..(i + 1) * n],
                &u_o[i * n..(i + 1) * n],
            );
            for j in 0..n {
                // ∂c'_i/∂h_j
                let dcp_dh = c[i] * df * ruf[j] + gg * di * rui[j] + ig * dg * rug[j];
                // ∂h'_i/∂h_j
                let dhp_dh = tc * do_ * ruo[j] + og * dtc * dcp_dh;
                out_jac[i * dim + j] = dhp_dh;
                out_jac[(n + i) * dim + j] = dcp_dh;
            }
            // ∂c'_i/∂c_i = f_i ; ∂h'_i/∂c_i = o_i·(1−tanh²)·f_i
            out_jac[(n + i) * dim + n + i] = fg;
            out_jac[i * dim + n + i] = og * dtc * fg;
        }
    }

    fn flops_step(&self) -> u64 {
        let (n, m) = (self.n as u64, self.m as u64);
        2 * 4 * n * (n + m) + 14 * n
    }

    fn flops_jacobian(&self) -> u64 {
        let n = self.n as u64;
        self.flops_step() + 8 * n * n + 12 * n
    }
}

impl<S: Scalar> CellGrad<S> for Lstm<S> {
    fn num_params(&self) -> usize {
        self.p.len()
    }
    fn params(&self) -> &[S] {
        &self.p
    }
    fn params_mut(&mut self) -> &mut [S] {
        &mut self.p
    }

    fn vjp_step(
        &self,
        s: &[S],
        x: &[S],
        lambda: &[S],
        dh_acc: &mut [S],
        mut dx: Option<&mut [S]>,
        dtheta: &mut [S],
        ws: &mut [S],
    ) {
        let n = self.n;
        let m = self.m;
        self.gates(s, x, ws);
        let h = &s[..n];
        let c = &s[n..2 * n];
        let (lam_h, lam_c) = lambda.split_at(n);

        // pre-activation adjoints per gate
        let mut da = vec![S::zero(); GATES * n];
        for i in 0..n {
            let ig = ws[i];
            let fg = ws[n + i];
            let gg = ws[2 * n + i];
            let og = ws[3 * n + i];
            let tc = ws[4 * n + i];
            let dtc = S::one() - tc * tc;

            // dL/dc' = λ_c + λ_h · o · (1−tanh²)
            let dcp = lam_c[i] + lam_h[i] * og * dtc;
            // o gate: h' = o·tanh(c')
            da[3 * n + i] = lam_h[i] * tc * (og * (S::one() - og));
            // f gate: c' = f·c + i·g
            da[n + i] = dcp * c[i] * (fg * (S::one() - fg));
            // i gate
            da[i] = dcp * gg * (ig * (S::one() - ig));
            // g gate
            da[2 * n + i] = dcp * ig * (S::one() - gg * gg);
            // direct dc path
            dh_acc[n + i] += dcp * fg;
        }

        for k in 0..GATES {
            let u = self.u(k);
            let w = self.w(k);
            let (ow, ou, ob) = (self.off_w(k), self.off_u(k), self.off_b(k));
            for i in 0..n {
                let a = da[k * n + i];
                if a == S::zero() {
                    continue;
                }
                let rowu = &u[i * n..(i + 1) * n];
                for j in 0..n {
                    dh_acc[j] += rowu[j] * a;
                    dtheta[ou + i * n + j] += a * h[j];
                }
                if let Some(dx) = dx.as_deref_mut() {
                    let roww = &w[i * m..(i + 1) * m];
                    for j in 0..m {
                        dx[j] += roww[j] * a;
                    }
                }
                for j in 0..m {
                    dtheta[ow + i * m + j] += a * x[j];
                }
                dtheta[ob + i] += a;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cells::test_support::{check_jacobian, check_vjp};

    #[test]
    fn jacobian_matches_fd() {
        let mut rng = Rng::new(8);
        for &(n, m) in &[(1usize, 1usize), (2, 3), (4, 2)] {
            let cell: Lstm<f64> = Lstm::new(n, m, &mut rng);
            check_jacobian(&cell, 300 + n as u64, 1e-6);
        }
    }

    #[test]
    fn vjp_matches_fd() {
        let mut rng = Rng::new(9);
        let cell: Lstm<f64> = Lstm::new(3, 2, &mut rng);
        check_vjp(&cell, 400, 1e-6);
    }

    #[test]
    fn state_dim_is_twice_hidden() {
        let mut rng = Rng::new(1);
        let cell: Lstm<f64> = Lstm::new(5, 2, &mut rng);
        assert_eq!(cell.state_dim(), 10);
        assert_eq!(cell.num_params(), 4 * (5 * 2 + 25 + 5));
    }

    #[test]
    fn cell_state_linear_in_c_when_gates_saturate() {
        // With zero params: i=f=o=1/2, g=0 → c' = c/2, h' = tanh(c/2)/2.
        let n = 2;
        let cell: Lstm<f64> = Lstm::from_params(n, 1, vec![0.0; 4 * (n + n * n + n)]);
        let s = vec![0.7, -0.7, 0.4, -1.0];
        let mut out = vec![0.0; 4];
        let mut ws = vec![0.0; cell.ws_len()];
        cell.step(&s, &[0.0], &mut out, &mut ws);
        assert!((out[2] - 0.2).abs() < 1e-14);
        assert!((out[3] + 0.5).abs() < 1e-14);
        assert!((out[0] - 0.5 * 0.2f64.tanh()).abs() < 1e-14);
    }
}
