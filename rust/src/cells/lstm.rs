//! LSTM (Hochreiter & Schmidhuber, 1997). The DEER framework treats the
//! packed state (dimension 2n) as the recurrent vector, stored
//! **interleaved**: `s = [h_0, c_0, h_1, c_1, …]`, so each unit's coupled
//! `(h_i, c_i)` pair occupies one contiguous 2-slot block. Under this
//! layout the 2n×2n state Jacobian
//!
//! ```text
//! ∂[h',c']/∂[h,c] = [ ∂h'/∂h  ∂h'/∂c ]
//!                   [ ∂c'/∂h  ∂c'/∂c ]
//! ```
//!
//! has its entire `∂·/∂c` half concentrated on the 2×2 unit diagonal
//! (`c'_i` and `h'_i` read only `c_i`), which is what the packed
//! [`Cell::jacobian_block`] kernels exploit: `Block(2)` slabs of
//! `[T, n, 2, 2]` instead of `[T, 2n, 2n]` dense. With diagonal recurrent
//! matrices `U_k` (the ParaRNN setting) the dense Jacobian *is*
//! block-diagonal and the Block(2) path is exact Newton; with dense `U_k`
//! it is the `BlockApprox` quasi mode (same fixed point).
//!
//! Equations:
//! ```text
//! i = σ(W_i x + U_i h + b_i)      f = σ(W_f x + U_f h + b_f)
//! g = tanh(W_g x + U_g h + b_g)   o = σ(W_o x + U_o h + b_o)
//! c' = f ⊙ c + i ⊙ g              h' = o ⊙ tanh(c')
//! ```
//!
//! The four input projections `W_k x + b_k` are trajectory-invariant, so
//! the cell supports [`Cell::precompute_x`] (4n per step) and the `*_pre`
//! Jacobian kernels read them instead of redoing the `W·x` matvecs every
//! Newton iteration.

use super::{init_uniform, sigmoid, Cell, CellGrad, JacobianStructure};
use crate::util::rng::Rng;
use crate::util::scalar::Scalar;

/// LSTM cell with `n` hidden units and `m` inputs; `state_dim() = 2n`
/// (interleaved `[h_0, c_0, h_1, c_1, …]`).
///
/// Parameter layout: `[W_i, W_f, W_g, W_o] (4·n·m)`,
/// `[U_i, U_f, U_g, U_o] (4·n·n)`, `[b_i, b_f, b_g, b_o] (4·n)`.
#[derive(Debug, Clone)]
pub struct Lstm<S> {
    n: usize,
    m: usize,
    p: Vec<S>,
}

const GATES: usize = 4; // i, f, g, o

// Workspace layout (ws_len = 7n):
// [i, f, g, o, tanh(c'), c'] gate values (6n) | unpacked h (n)

impl<S: Scalar> Lstm<S> {
    pub fn new(n: usize, m: usize, rng: &mut Rng) -> Self {
        let mut p = vec![S::zero(); GATES * (n * m + n * n + n)];
        init_uniform(&mut p, n, rng);
        Lstm { n, m, p }
    }

    pub fn from_params(n: usize, m: usize, p: Vec<S>) -> Self {
        assert_eq!(p.len(), GATES * (n * m + n * n + n));
        Lstm { n, m, p }
    }

    fn w(&self, k: usize) -> &[S] {
        let (n, m) = (self.n, self.m);
        &self.p[k * n * m..(k + 1) * n * m]
    }
    fn u(&self, k: usize) -> &[S] {
        let (n, m) = (self.n, self.m);
        let base = GATES * n * m;
        &self.p[base + k * n * n..base + (k + 1) * n * n]
    }
    fn b(&self, k: usize) -> &[S] {
        let (n, m) = (self.n, self.m);
        let base = GATES * (n * m + n * n);
        &self.p[base + k * n..base + (k + 1) * n]
    }
    fn off_w(&self, k: usize) -> usize {
        k * self.n * self.m
    }
    fn off_u(&self, k: usize) -> usize {
        GATES * self.n * self.m + k * self.n * self.n
    }
    fn off_b(&self, k: usize) -> usize {
        GATES * (self.n * self.m + self.n * self.n) + k * self.n
    }

    /// Gate activations into ws: [i, f, g, o, tanh(c'), c'] each length n,
    /// plus the unpacked contiguous h copy at ws[6n..7n]. `c_i` is read
    /// straight from the interleaved state (`s[2i+1]`).
    ///
    /// The pre-activation base is either computed inline from `x` (direct
    /// path, `pre = None`) or read from the trajectory-invariant
    /// projections of [`Cell::precompute_x`] (`pre = Some`, `x` unused) —
    /// ONE implementation owns the bitwise-sensitive accumulation order
    /// (bias + W·x first, then U·h), so the two paths cannot drift.
    #[inline]
    fn gates(&self, s: &[S], x: &[S], pre: Option<&[S]>, ws: &mut [S]) {
        let n = self.n;
        let m = self.m;
        let (gv, hbuf) = ws.split_at_mut(6 * n);
        let hbuf = &mut hbuf[..n];
        for i in 0..n {
            hbuf[i] = s[2 * i];
        }
        let hbuf = &hbuf[..];
        for k in 0..GATES {
            let u = self.u(k);
            for i in 0..n {
                let mut a = match pre {
                    Some(p) => p[k * n + i],
                    None => {
                        let w = self.w(k);
                        let b = self.b(k);
                        let mut a = b[i];
                        let roww = &w[i * m..(i + 1) * m];
                        for j in 0..m {
                            a += roww[j] * x[j];
                        }
                        a
                    }
                };
                let rowu = &u[i * n..(i + 1) * n];
                for j in 0..n {
                    a += rowu[j] * hbuf[j];
                }
                gv[k * n + i] = if k == 2 { a.tanh() } else { sigmoid(a) };
            }
        }
        for i in 0..n {
            let cp = gv[n + i] * s[2 * i + 1] + gv[i] * gv[2 * n + i]; // f·c + i·g
            gv[5 * n + i] = cp;
            gv[4 * n + i] = cp.tanh();
        }
    }

    /// Shared tail of the dense Jacobian kernels (after [`Lstm::gates`]).
    #[inline]
    fn jacobian_from_gates(&self, s: &[S], out_f: &mut [S], out_jac: &mut [S], gv: &[S]) {
        let n = self.n;
        let dim = 2 * n;
        let (u_i, u_f, u_g, u_o) = (self.u(0), self.u(1), self.u(2), self.u(3));
        for v in out_jac.iter_mut() {
            *v = S::zero();
        }
        for i in 0..n {
            let ig = gv[i];
            let fg = gv[n + i];
            let gg = gv[2 * n + i];
            let og = gv[3 * n + i];
            let tc = gv[4 * n + i];
            let cp = gv[5 * n + i];
            let ci = s[2 * i + 1];
            out_f[2 * i] = og * tc;
            out_f[2 * i + 1] = cp;

            let di = ig * (S::one() - ig);
            let df = fg * (S::one() - fg);
            let dg = S::one() - gg * gg;
            let do_ = og * (S::one() - og);
            let dtc = S::one() - tc * tc;

            let (rui, ruf, rug, ruo) = (
                &u_i[i * n..(i + 1) * n],
                &u_f[i * n..(i + 1) * n],
                &u_g[i * n..(i + 1) * n],
                &u_o[i * n..(i + 1) * n],
            );
            for j in 0..n {
                // ∂c'_i/∂h_j
                let dcp_dh = ci * df * ruf[j] + gg * di * rui[j] + ig * dg * rug[j];
                // ∂h'_i/∂h_j
                let dhp_dh = tc * do_ * ruo[j] + og * dtc * dcp_dh;
                out_jac[(2 * i) * dim + 2 * j] = dhp_dh;
                out_jac[(2 * i + 1) * dim + 2 * j] = dcp_dh;
            }
            // ∂c'_i/∂c_i = f_i ; ∂h'_i/∂c_i = o_i·(1−tanh²)·f_i
            out_jac[(2 * i + 1) * dim + 2 * i + 1] = fg;
            out_jac[(2 * i) * dim + 2 * i + 1] = og * dtc * fg;
        }
    }

    /// Shared tail of the packed Block(2) kernels: block i is the 2×2 tile
    /// `[[∂h'_i/∂h_i, ∂h'_i/∂c_i], [∂c'_i/∂h_i, ∂c'_i/∂c_i]]`, each entry
    /// computed with the exact expression of the dense kernel at (i, i) —
    /// bitwise identical to the corresponding dense entries, O(n) beyond
    /// the gate math instead of O(n²).
    #[inline]
    fn jacobian_block_from_gates(&self, s: &[S], out_f: &mut [S], out_jblk: &mut [S], gv: &[S]) {
        let n = self.n;
        let (u_i, u_f, u_g, u_o) = (self.u(0), self.u(1), self.u(2), self.u(3));
        for i in 0..n {
            let ig = gv[i];
            let fg = gv[n + i];
            let gg = gv[2 * n + i];
            let og = gv[3 * n + i];
            let tc = gv[4 * n + i];
            let cp = gv[5 * n + i];
            let ci = s[2 * i + 1];
            out_f[2 * i] = og * tc;
            out_f[2 * i + 1] = cp;

            let di = ig * (S::one() - ig);
            let df = fg * (S::one() - fg);
            let dg = S::one() - gg * gg;
            let do_ = og * (S::one() - og);
            let dtc = S::one() - tc * tc;

            let (rui, ruf, rug, ruo) = (
                &u_i[i * n..(i + 1) * n],
                &u_f[i * n..(i + 1) * n],
                &u_g[i * n..(i + 1) * n],
                &u_o[i * n..(i + 1) * n],
            );
            let dcp_dh = ci * df * ruf[i] + gg * di * rui[i] + ig * dg * rug[i];
            let dhp_dh = tc * do_ * ruo[i] + og * dtc * dcp_dh;
            out_jblk[i * 4] = dhp_dh; // ∂h'_i/∂h_i
            out_jblk[i * 4 + 1] = og * dtc * fg; // ∂h'_i/∂c_i
            out_jblk[i * 4 + 2] = dcp_dh; // ∂c'_i/∂h_i
            out_jblk[i * 4 + 3] = fg; // ∂c'_i/∂c_i
        }
    }
}

impl<S: Scalar> Cell<S> for Lstm<S> {
    fn state_dim(&self) -> usize {
        2 * self.n
    }
    fn input_dim(&self) -> usize {
        self.m
    }
    fn ws_len(&self) -> usize {
        7 * self.n
    }

    /// The natural ParaRNN pairing: each unit's `(h_i, c_i)` 2-block.
    fn block_k(&self) -> Option<usize> {
        Some(2)
    }

    fn jacobian_structure(&self) -> JacobianStructure {
        // The exact Jacobian is dense through the U_k recurrences (Full
        // mode stays exact Newton); Block(2) is reachable via
        // `JacobianMode::BlockApprox` and exact when the U_k are diagonal.
        JacobianStructure::Dense
    }

    fn step(&self, s: &[S], x: &[S], out: &mut [S], ws: &mut [S]) {
        let n = self.n;
        self.gates(s, x, None, ws);
        for i in 0..n {
            out[2 * i] = ws[3 * n + i] * ws[4 * n + i]; // h' = o·tanh(c')
            out[2 * i + 1] = ws[5 * n + i]; // c'
        }
    }

    fn jacobian(&self, s: &[S], x: &[S], out_f: &mut [S], out_jac: &mut [S], ws: &mut [S]) {
        self.gates(s, x, None, ws);
        self.jacobian_from_gates(s, out_f, out_jac, &ws[..6 * self.n]);
    }

    fn x_precompute_len(&self) -> usize {
        GATES * self.n
    }

    /// `out[t] = [W_i x + b_i, W_f x + b_f, W_g x + b_g, W_o x + b_o]` —
    /// everything independent of the trajectory guess, computed once per
    /// DEER evaluation (§Perf). Accumulation order (bias first, then the
    /// input j-loop) matches [`Lstm::gates`] bitwise.
    fn precompute_x(&self, xs: &[S], out: &mut [S]) {
        let n = self.n;
        let m = self.m;
        let t_len = xs.len() / m;
        debug_assert_eq!(out.len(), t_len * GATES * n);
        for t in 0..t_len {
            let x = &xs[t * m..(t + 1) * m];
            let o = &mut out[t * GATES * n..(t + 1) * GATES * n];
            for k in 0..GATES {
                let w = self.w(k);
                let b = self.b(k);
                for i in 0..n {
                    let mut a = b[i];
                    let roww = &w[i * m..(i + 1) * m];
                    for j in 0..m {
                        a += roww[j] * x[j];
                    }
                    o[k * n + i] = a;
                }
            }
        }
    }

    fn jacobian_pre(&self, s: &[S], pre: &[S], out_f: &mut [S], out_jac: &mut [S], ws: &mut [S]) {
        self.gates(s, &[], Some(pre), ws);
        self.jacobian_from_gates(s, out_f, out_jac, &ws[..6 * self.n]);
    }

    fn jacobian_block(&self, s: &[S], x: &[S], out_f: &mut [S], out_jblk: &mut [S], ws: &mut [S]) {
        self.gates(s, x, None, ws);
        self.jacobian_block_from_gates(s, out_f, out_jblk, &ws[..6 * self.n]);
    }

    fn jacobian_block_pre(
        &self,
        s: &[S],
        pre: &[S],
        out_f: &mut [S],
        out_jblk: &mut [S],
        ws: &mut [S],
    ) {
        self.gates(s, &[], Some(pre), ws);
        self.jacobian_block_from_gates(s, out_f, out_jblk, &ws[..6 * self.n]);
    }

    /// Fused batched Block(2) FUNCEVAL kernel (the ROADMAP follow-up from
    /// the Block(k) PR): the batch axis is folded into the recurrent gate
    /// matmuls — the unit loop is outermost so each `U_k[i, :]` row is
    /// loaded once and streamed across all B elements instead of being
    /// re-fetched B times. Everything the 2×2 block needs is per-unit
    /// local (the `∂·/∂c` half lives on the unit diagonal), so no gate
    /// slabs are staged. Per-element accumulation order is identical to
    /// [`Lstm::gates`] + [`Lstm::jacobian_block_from_gates`] (pre-computed
    /// base first, then the `U·h` j-loop), so the result is **bitwise**
    /// equal to the looped default — the driver's fused-vs-per-element
    /// dispatch never changes numerics.
    fn jacobian_pre_block_batch(
        &self,
        hs: &[S],
        pres: &[S],
        out_f: &mut [S],
        out_jblk: &mut [S],
        ws: &mut [S],
        batch: usize,
    ) {
        let n = self.n;
        let dim = 2 * n;
        let pl = GATES * n;
        let bl = dim * 2; // packed [n, 2, 2] per element
        let _ = ws;
        debug_assert_eq!(hs.len(), batch * dim);
        debug_assert_eq!(pres.len(), batch * pl);
        debug_assert_eq!(out_f.len(), batch * dim);
        debug_assert_eq!(out_jblk.len(), batch * bl);
        let (u_i, u_f, u_g, u_o) = (self.u(0), self.u(1), self.u(2), self.u(3));
        for i in 0..n {
            let (rui, ruf, rug, ruo) = (
                &u_i[i * n..(i + 1) * n],
                &u_f[i * n..(i + 1) * n],
                &u_g[i * n..(i + 1) * n],
                &u_o[i * n..(i + 1) * n],
            );
            for b in 0..batch {
                let s = &hs[b * dim..(b + 1) * dim];
                let pre = &pres[b * pl..(b + 1) * pl];
                // gate pre-activations: pre base, then U·h in j order —
                // h_j is read interleaved (s[2j]), matching gates()'s
                // unpacked hbuf values bitwise
                let mut ai = pre[i];
                let mut af = pre[n + i];
                let mut ag = pre[2 * n + i];
                let mut ao = pre[3 * n + i];
                for j in 0..n {
                    let hj = s[2 * j];
                    ai += rui[j] * hj;
                    af += ruf[j] * hj;
                    ag += rug[j] * hj;
                    ao += ruo[j] * hj;
                }
                let ig = sigmoid(ai);
                let fg = sigmoid(af);
                let gg = ag.tanh();
                let og = sigmoid(ao);
                let ci = s[2 * i + 1];
                let cp = fg * ci + ig * gg;
                let tc = cp.tanh();
                out_f[b * dim + 2 * i] = og * tc;
                out_f[b * dim + 2 * i + 1] = cp;

                let di = ig * (S::one() - ig);
                let df = fg * (S::one() - fg);
                let dg = S::one() - gg * gg;
                let do_ = og * (S::one() - og);
                let dtc = S::one() - tc * tc;
                let dcp_dh = ci * df * ruf[i] + gg * di * rui[i] + ig * dg * rug[i];
                let dhp_dh = tc * do_ * ruo[i] + og * dtc * dcp_dh;
                let blk = &mut out_jblk[b * bl + i * 4..b * bl + (i + 1) * 4];
                blk[0] = dhp_dh; // ∂h'_i/∂h_i
                blk[1] = og * dtc * fg; // ∂h'_i/∂c_i
                blk[2] = dcp_dh; // ∂c'_i/∂h_i
                blk[3] = fg; // ∂c'_i/∂c_i
            }
        }
    }

    fn flops_step(&self) -> u64 {
        let (n, m) = (self.n as u64, self.m as u64);
        2 * 4 * n * (n + m) + 14 * n
    }

    fn flops_jacobian(&self) -> u64 {
        let n = self.n as u64;
        self.flops_step() + 8 * n * n + 12 * n
    }
}

impl<S: Scalar> CellGrad<S> for Lstm<S> {
    fn num_params(&self) -> usize {
        self.p.len()
    }
    fn params(&self) -> &[S] {
        &self.p
    }
    fn params_mut(&mut self) -> &mut [S] {
        &mut self.p
    }

    fn vjp_step(
        &self,
        s: &[S],
        x: &[S],
        lambda: &[S],
        dh_acc: &mut [S],
        mut dx: Option<&mut [S]>,
        dtheta: &mut [S],
        ws: &mut [S],
    ) {
        let n = self.n;
        let m = self.m;
        self.gates(s, x, None, ws);
        let (gv, hbuf) = ws.split_at(6 * n);
        let hbuf = &hbuf[..n];

        // pre-activation adjoints per gate; λ components read interleaved:
        // λ_h_i = lambda[2i], λ_c_i = lambda[2i+1]
        let mut da = vec![S::zero(); GATES * n];
        for i in 0..n {
            let ig = gv[i];
            let fg = gv[n + i];
            let gg = gv[2 * n + i];
            let og = gv[3 * n + i];
            let tc = gv[4 * n + i];
            let dtc = S::one() - tc * tc;
            let lam_h = lambda[2 * i];
            let lam_c = lambda[2 * i + 1];
            let ci = s[2 * i + 1];

            // dL/dc' = λ_c + λ_h · o · (1−tanh²)
            let dcp = lam_c + lam_h * og * dtc;
            // o gate: h' = o·tanh(c')
            da[3 * n + i] = lam_h * tc * (og * (S::one() - og));
            // f gate: c' = f·c + i·g
            da[n + i] = dcp * ci * (fg * (S::one() - fg));
            // i gate
            da[i] = dcp * gg * (ig * (S::one() - ig));
            // g gate
            da[2 * n + i] = dcp * ig * (S::one() - gg * gg);
            // direct dc path
            dh_acc[2 * i + 1] += dcp * fg;
        }

        for k in 0..GATES {
            let u = self.u(k);
            let w = self.w(k);
            let (ow, ou, ob) = (self.off_w(k), self.off_u(k), self.off_b(k));
            for i in 0..n {
                let a = da[k * n + i];
                if a == S::zero() {
                    continue;
                }
                let rowu = &u[i * n..(i + 1) * n];
                for j in 0..n {
                    dh_acc[2 * j] += rowu[j] * a;
                    dtheta[ou + i * n + j] += a * hbuf[j];
                }
                if let Some(dx) = dx.as_deref_mut() {
                    let roww = &w[i * m..(i + 1) * m];
                    for j in 0..m {
                        dx[j] += roww[j] * a;
                    }
                }
                for j in 0..m {
                    dtheta[ow + i * m + j] += a * x[j];
                }
                dtheta[ob + i] += a;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cells::test_support::{check_jacobian, check_vjp};

    #[test]
    fn jacobian_matches_fd() {
        let mut rng = Rng::new(8);
        for &(n, m) in &[(1usize, 1usize), (2, 3), (4, 2)] {
            let cell: Lstm<f64> = Lstm::new(n, m, &mut rng);
            check_jacobian(&cell, 300 + n as u64, 1e-6);
        }
    }

    #[test]
    fn vjp_matches_fd() {
        let mut rng = Rng::new(9);
        let cell: Lstm<f64> = Lstm::new(3, 2, &mut rng);
        check_vjp(&cell, 400, 1e-6);
    }

    #[test]
    fn state_dim_is_twice_hidden() {
        let mut rng = Rng::new(1);
        let cell: Lstm<f64> = Lstm::new(5, 2, &mut rng);
        assert_eq!(cell.state_dim(), 10);
        assert_eq!(cell.num_params(), 4 * (5 * 2 + 25 + 5));
        assert_eq!(cell.block_k(), Some(2));
    }

    #[test]
    fn cell_state_linear_in_c_when_gates_saturate() {
        // With zero params: i=f=o=1/2, g=0 → c' = c/2, h' = tanh(c/2)/2.
        // Interleaved state: [h_0, c_0, h_1, c_1].
        let n = 2;
        let cell: Lstm<f64> = Lstm::from_params(n, 1, vec![0.0; 4 * (n + n * n + n)]);
        let s = vec![0.7, 0.4, -0.7, -1.0];
        let mut out = vec![0.0; 4];
        let mut ws = vec![0.0; cell.ws_len()];
        cell.step(&s, &[0.0], &mut out, &mut ws);
        assert!((out[1] - 0.2).abs() < 1e-14); // c'_0 = 0.4/2
        assert!((out[3] + 0.5).abs() < 1e-14); // c'_1 = −1.0/2
        assert!((out[0] - 0.5 * 0.2f64.tanh()).abs() < 1e-14); // h'_0
        assert!((out[2] - 0.5 * (-0.5f64).tanh()).abs() < 1e-14); // h'_1
    }

    /// The packed Block(2) kernel must reproduce the dense Jacobian's
    /// in-block entries bitwise (and the same f), directly and through the
    /// precomputed-input path.
    #[test]
    fn block_kernel_matches_dense_blocks_bitwise() {
        let mut rng = Rng::new(17);
        for &(n, m) in &[(1usize, 1usize), (3, 2), (5, 4)] {
            let cell: Lstm<f64> = Lstm::new(n, m, &mut rng);
            let dim = 2 * n;
            let mut s = vec![0.0; dim];
            let mut x = vec![0.0; m];
            rng.fill_normal(&mut s, 0.8);
            rng.fill_normal(&mut x, 1.0);
            let mut ws = vec![0.0; cell.ws_len()];

            let mut f_d = vec![0.0; dim];
            let mut jac = vec![0.0; dim * dim];
            cell.jacobian(&s, &x, &mut f_d, &mut jac, &mut ws);

            let mut f_b = vec![0.0; dim];
            let mut jblk = vec![0.0; dim * 2];
            cell.jacobian_block(&s, &x, &mut f_b, &mut jblk, &mut ws);
            assert_eq!(f_d, f_b, "n={n}: block f");
            for i in 0..n {
                for r in 0..2 {
                    for c in 0..2 {
                        assert_eq!(
                            jblk[i * 4 + r * 2 + c],
                            jac[(2 * i + r) * dim + 2 * i + c],
                            "n={n} block {i} ({r},{c})"
                        );
                    }
                }
            }

            // precomputed-input path, bitwise equal to the direct one
            let pl = cell.x_precompute_len();
            let mut pre = vec![0.0; pl];
            cell.precompute_x(&x, &mut pre);
            let mut f_p = vec![0.0; dim];
            let mut jac_p = vec![0.0; dim * dim];
            cell.jacobian_pre(&s, &pre, &mut f_p, &mut jac_p, &mut ws);
            assert_eq!(f_p, f_d, "n={n}: jacobian_pre f");
            assert_eq!(jac_p, jac, "n={n}: jacobian_pre jac");
            let mut f_bp = vec![0.0; dim];
            let mut jblk_p = vec![0.0; dim * 2];
            cell.jacobian_block_pre(&s, &pre, &mut f_bp, &mut jblk_p, &mut ws);
            assert_eq!(f_bp, f_b, "n={n}: jacobian_block_pre f");
            assert_eq!(jblk_p, jblk, "n={n}: jacobian_block_pre blocks");
        }
    }

    /// With diagonal recurrent matrices U_k the dense Jacobian is exactly
    /// block-diagonal — every off-block entry is zero (the ParaRNN setting
    /// where the Block(2) path is exact Newton).
    #[test]
    fn diagonal_recurrence_makes_jacobian_block_diagonal() {
        let (n, m) = (3usize, 2usize);
        let mut rng = Rng::new(23);
        let mut cell: Lstm<f64> = Lstm::new(n, m, &mut rng);
        let ubase = GATES * n * m;
        for k in 0..GATES {
            for i in 0..n {
                for j in 0..n {
                    if i != j {
                        cell.params_mut()[ubase + k * n * n + i * n + j] = 0.0;
                    }
                }
            }
        }
        let dim = 2 * n;
        let mut s = vec![0.0; dim];
        let mut x = vec![0.0; m];
        rng.fill_normal(&mut s, 0.8);
        rng.fill_normal(&mut x, 1.0);
        let mut ws = vec![0.0; cell.ws_len()];
        let mut f = vec![0.0; dim];
        let mut jac = vec![0.0; dim * dim];
        cell.jacobian(&s, &x, &mut f, &mut jac, &mut ws);
        for r in 0..dim {
            for c in 0..dim {
                if r / 2 != c / 2 {
                    assert_eq!(jac[r * dim + c], 0.0, "off-block ({r},{c}) nonzero");
                }
            }
        }
    }
}
