//! LSTM with **diagonal recurrent weights** — the ParaRNN-style variant
//! whose interleaved-state Jacobian is *natively* `Block(2)`: each gate of
//! unit `i` reads only `h_i` (and `c'` only `c_i`), so the 2n×2n Jacobian
//! is exactly the per-unit 2×2 tiles `[[∂h'/∂h, ∂h'/∂c], [∂c'/∂h,
//! ∂c'/∂c]]` and DEER's Full mode is exact Newton through the packed
//! O(n·k²) kernels of [`crate::scan::block`] (no `BlockApprox` needed).
//!
//! Equations (the standard LSTM with `U_k = diag(u_k)`):
//! ```text
//! i = σ(W_i x + u_i ⊙ h + b_i)      f = σ(W_f x + u_f ⊙ h + b_f)
//! g = tanh(W_g x + u_g ⊙ h + b_g)   o = σ(W_o x + u_o ⊙ h + b_o)
//! c' = f ⊙ c + i ⊙ g                h' = o ⊙ tanh(c')
//! ```
//!
//! State is interleaved like [`super::Lstm`]: `s = [h_0, c_0, h_1, c_1,
//! …]`, `state_dim() = 2n`. A `DiagLstm` is numerically identical to a
//! [`super::Lstm`] whose `U_k` are the diagonal embeddings of `u_k` (the
//! setting [`super::Lstm`]'s `diagonal_recurrence_makes_jacobian_block_diagonal`
//! test pins); the tests here pin that equivalence directly.

use super::{init_uniform, sigmoid, Cell, CellGrad, JacobianStructure};
use crate::util::rng::Rng;
use crate::util::scalar::Scalar;

/// Diagonal-recurrence LSTM with `n` hidden units and `m` inputs;
/// `state_dim() = 2n` (interleaved `[h_0, c_0, h_1, c_1, …]`).
///
/// Parameter layout: `[W_i, W_f, W_g, W_o] (4·n·m)`,
/// `[u_i, u_f, u_g, u_o] (4·n)`, `[b_i, b_f, b_g, b_o] (4·n)`.
#[derive(Debug, Clone)]
pub struct DiagLstm<S> {
    n: usize,
    m: usize,
    p: Vec<S>,
}

const GATES: usize = 4; // i, f, g, o

// Workspace layout (ws_len = 6n): [i, f, g, o, tanh(c'), c'] gate values

impl<S: Scalar> DiagLstm<S> {
    /// New cell, uniform(-1/√n) init; recurrent gains shrunk inside the
    /// unit circle like [`super::IndRnn`].
    pub fn new(n: usize, m: usize, rng: &mut Rng) -> Self {
        let mut p = vec![S::zero(); GATES * (n * m + 2 * n)];
        init_uniform(&mut p, n, rng);
        let u_lo = GATES * n * m;
        for v in p[u_lo..u_lo + GATES * n].iter_mut() {
            *v = *v * S::from_f64c(0.9);
        }
        DiagLstm { n, m, p }
    }

    /// Construct from an existing flat parameter vector.
    pub fn from_params(n: usize, m: usize, p: Vec<S>) -> Self {
        assert_eq!(p.len(), GATES * (n * m + 2 * n));
        DiagLstm { n, m, p }
    }

    fn w(&self, k: usize) -> &[S] {
        let (n, m) = (self.n, self.m);
        &self.p[k * n * m..(k + 1) * n * m]
    }
    fn u(&self, k: usize) -> &[S] {
        let (n, m) = (self.n, self.m);
        let base = GATES * n * m;
        &self.p[base + k * n..base + (k + 1) * n]
    }
    fn b(&self, k: usize) -> &[S] {
        let (n, m) = (self.n, self.m);
        let base = GATES * (n * m + n);
        &self.p[base + k * n..base + (k + 1) * n]
    }
    fn off_w(&self, k: usize) -> usize {
        k * self.n * self.m
    }
    fn off_u(&self, k: usize) -> usize {
        GATES * self.n * self.m + k * self.n
    }
    fn off_b(&self, k: usize) -> usize {
        GATES * (self.n * self.m + self.n) + k * self.n
    }

    /// Gate activations into ws: `[i, f, g, o, tanh(c'), c']` each length
    /// n. The pre-activation base is either computed inline from `x`
    /// (direct path, `pre = None`) or read from the trajectory-invariant
    /// projections of [`Cell::precompute_x`] (`pre = Some`, `x` unused) —
    /// ONE implementation owns the bitwise-sensitive accumulation order
    /// (bias + W·x first, then the `u ⊙ h` recurrent term).
    #[inline]
    fn gates(&self, s: &[S], x: &[S], pre: Option<&[S]>, ws: &mut [S]) {
        let n = self.n;
        let m = self.m;
        for k in 0..GATES {
            let u = self.u(k);
            for i in 0..n {
                let a = match pre {
                    Some(p) => p[k * n + i],
                    None => {
                        let w = self.w(k);
                        let b = self.b(k);
                        let mut a = b[i];
                        let roww = &w[i * m..(i + 1) * m];
                        for j in 0..m {
                            a += roww[j] * x[j];
                        }
                        a
                    }
                };
                let a = a + u[i] * s[2 * i];
                ws[k * n + i] = if k == 2 { a.tanh() } else { sigmoid(a) };
            }
        }
        for i in 0..n {
            let cp = ws[n + i] * s[2 * i + 1] + ws[i] * ws[2 * n + i]; // f·c + i·g
            ws[5 * n + i] = cp;
            ws[4 * n + i] = cp.tanh();
        }
    }

    /// Shared tail of the packed Block(2) kernels: block i is the 2×2 tile
    /// `[[∂h'_i/∂h_i, ∂h'_i/∂c_i], [∂c'_i/∂h_i, ∂c'_i/∂c_i]]` — the exact
    /// expressions of the dense [`super::Lstm`] kernel with the recurrent
    /// rows collapsed to the `u_k[i]` diagonals.
    #[inline]
    fn block_from_gates(&self, s: &[S], out_f: &mut [S], out_jblk: &mut [S], gv: &[S]) {
        let n = self.n;
        let (u_i, u_f, u_g, u_o) = (self.u(0), self.u(1), self.u(2), self.u(3));
        for i in 0..n {
            let ig = gv[i];
            let fg = gv[n + i];
            let gg = gv[2 * n + i];
            let og = gv[3 * n + i];
            let tc = gv[4 * n + i];
            let cp = gv[5 * n + i];
            let ci = s[2 * i + 1];
            out_f[2 * i] = og * tc;
            out_f[2 * i + 1] = cp;

            let di = ig * (S::one() - ig);
            let df = fg * (S::one() - fg);
            let dg = S::one() - gg * gg;
            let do_ = og * (S::one() - og);
            let dtc = S::one() - tc * tc;

            let dcp_dh = ci * df * u_f[i] + gg * di * u_i[i] + ig * dg * u_g[i];
            let dhp_dh = tc * do_ * u_o[i] + og * dtc * dcp_dh;
            out_jblk[i * 4] = dhp_dh; // ∂h'_i/∂h_i
            out_jblk[i * 4 + 1] = og * dtc * fg; // ∂h'_i/∂c_i
            out_jblk[i * 4 + 2] = dcp_dh; // ∂c'_i/∂h_i
            out_jblk[i * 4 + 3] = fg; // ∂c'_i/∂c_i
        }
    }
}

impl<S: Scalar> Cell<S> for DiagLstm<S> {
    fn state_dim(&self) -> usize {
        2 * self.n
    }
    fn input_dim(&self) -> usize {
        self.m
    }
    fn ws_len(&self) -> usize {
        6 * self.n
    }

    fn block_k(&self) -> Option<usize> {
        Some(2)
    }

    /// Natively `Block(2)`: the diagonal recurrences concentrate the whole
    /// Jacobian on the per-unit 2×2 tiles, so Full mode takes the packed
    /// path as exact Newton.
    fn jacobian_structure(&self) -> JacobianStructure {
        JacobianStructure::Block { k: 2 }
    }

    fn step(&self, s: &[S], x: &[S], out: &mut [S], ws: &mut [S]) {
        let n = self.n;
        self.gates(s, x, None, ws);
        for i in 0..n {
            out[2 * i] = ws[3 * n + i] * ws[4 * n + i]; // h' = o·tanh(c')
            out[2 * i + 1] = ws[5 * n + i]; // c'
        }
    }

    fn jacobian(&self, s: &[S], x: &[S], out_f: &mut [S], out_jac: &mut [S], ws: &mut [S]) {
        // Dense emission kept for the generic path: the 2×2 tiles embedded
        // in the zeroed 2n×2n matrix.
        let n = self.n;
        let dim = 2 * n;
        for v in out_jac.iter_mut() {
            *v = S::zero();
        }
        self.gates(s, x, None, ws);
        let mut blk = vec![S::zero(); dim * 2];
        self.block_from_gates(s, out_f, &mut blk, &ws[..6 * n]);
        for i in 0..n {
            out_jac[(2 * i) * dim + 2 * i] = blk[i * 4];
            out_jac[(2 * i) * dim + 2 * i + 1] = blk[i * 4 + 1];
            out_jac[(2 * i + 1) * dim + 2 * i] = blk[i * 4 + 2];
            out_jac[(2 * i + 1) * dim + 2 * i + 1] = blk[i * 4 + 3];
        }
    }

    fn jacobian_block(&self, s: &[S], x: &[S], out_f: &mut [S], out_jblk: &mut [S], ws: &mut [S]) {
        self.gates(s, x, None, ws);
        self.block_from_gates(s, out_f, out_jblk, &ws[..6 * self.n]);
    }

    fn jacobian_block_pre(
        &self,
        s: &[S],
        pre: &[S],
        out_f: &mut [S],
        out_jblk: &mut [S],
        ws: &mut [S],
    ) {
        self.gates(s, &[], Some(pre), ws);
        self.block_from_gates(s, out_f, out_jblk, &ws[..6 * self.n]);
    }

    fn x_precompute_len(&self) -> usize {
        GATES * self.n
    }

    /// `out[t] = [W_i x + b_i, W_f x + b_f, W_g x + b_g, W_o x + b_o]` —
    /// identical layout and accumulation order to
    /// [`super::Lstm::precompute_x`].
    fn precompute_x(&self, xs: &[S], out: &mut [S]) {
        let n = self.n;
        let m = self.m;
        let t_len = xs.len() / m;
        debug_assert_eq!(out.len(), t_len * GATES * n);
        for t in 0..t_len {
            let x = &xs[t * m..(t + 1) * m];
            let o = &mut out[t * GATES * n..(t + 1) * GATES * n];
            for k in 0..GATES {
                let w = self.w(k);
                let b = self.b(k);
                for i in 0..n {
                    let mut a = b[i];
                    let roww = &w[i * m..(i + 1) * m];
                    for j in 0..m {
                        a += roww[j] * x[j];
                    }
                    o[k * n + i] = a;
                }
            }
        }
    }

    fn jacobian_pre(&self, s: &[S], pre: &[S], out_f: &mut [S], out_jac: &mut [S], ws: &mut [S]) {
        let n = self.n;
        let dim = 2 * n;
        for v in out_jac.iter_mut() {
            *v = S::zero();
        }
        self.gates(s, &[], Some(pre), ws);
        let mut blk = vec![S::zero(); dim * 2];
        self.block_from_gates(s, out_f, &mut blk, &ws[..6 * n]);
        for i in 0..n {
            out_jac[(2 * i) * dim + 2 * i] = blk[i * 4];
            out_jac[(2 * i) * dim + 2 * i + 1] = blk[i * 4 + 1];
            out_jac[(2 * i + 1) * dim + 2 * i] = blk[i * 4 + 2];
            out_jac[(2 * i + 1) * dim + 2 * i + 1] = blk[i * 4 + 3];
        }
    }

    /// Fused batched step: the recurrence is elementwise, so the unit loop
    /// is outermost and each weight row streams across all B elements.
    /// Per-element accumulation order is identical to [`DiagLstm::gates`],
    /// so the result is **bitwise** equal to the looped default.
    fn step_batch(&self, hs: &[S], xs: &[S], out: &mut [S], ws: &mut [S], batch: usize) {
        let n = self.n;
        let m = self.m;
        let dim = 2 * n;
        let _ = ws;
        debug_assert_eq!(hs.len(), batch * dim);
        debug_assert_eq!(xs.len(), batch * m);
        debug_assert_eq!(out.len(), batch * dim);
        let (w_i, w_f, w_g, w_o) = (self.w(0), self.w(1), self.w(2), self.w(3));
        let (u_i, u_f, u_g, u_o) = (self.u(0), self.u(1), self.u(2), self.u(3));
        let (b_i, b_f, b_g, b_o) = (self.b(0), self.b(1), self.b(2), self.b(3));
        for i in 0..n {
            let (rwi, rwf, rwg, rwo) = (
                &w_i[i * m..(i + 1) * m],
                &w_f[i * m..(i + 1) * m],
                &w_g[i * m..(i + 1) * m],
                &w_o[i * m..(i + 1) * m],
            );
            for s in 0..batch {
                let st = &hs[s * dim..(s + 1) * dim];
                let x = &xs[s * m..(s + 1) * m];
                let mut ai = b_i[i];
                let mut af = b_f[i];
                let mut ag = b_g[i];
                let mut ao = b_o[i];
                for j in 0..m {
                    let xj = x[j];
                    ai += rwi[j] * xj;
                    af += rwf[j] * xj;
                    ag += rwg[j] * xj;
                    ao += rwo[j] * xj;
                }
                let hi = st[2 * i];
                let ci = st[2 * i + 1];
                let ig = sigmoid(ai + u_i[i] * hi);
                let fg = sigmoid(af + u_f[i] * hi);
                let gg = (ag + u_g[i] * hi).tanh();
                let og = sigmoid(ao + u_o[i] * hi);
                let cp = fg * ci + ig * gg;
                out[s * dim + 2 * i] = og * cp.tanh();
                out[s * dim + 2 * i + 1] = cp;
            }
        }
    }

    /// Fused batched Block(2) FUNCEVAL kernel — the packed-block hot path:
    /// the recurrence is elementwise, so the unit loop is outermost and
    /// each `u_k[i]` streams across all B elements. Per-element arithmetic
    /// is identical to [`DiagLstm::gates`] + [`DiagLstm::block_from_gates`],
    /// hence **bitwise** equal to the looped default.
    fn jacobian_pre_block_batch(
        &self,
        hs: &[S],
        pres: &[S],
        out_f: &mut [S],
        out_jblk: &mut [S],
        ws: &mut [S],
        batch: usize,
    ) {
        let n = self.n;
        let dim = 2 * n;
        let pl = GATES * n;
        let bl = dim * 2; // packed [n, 2, 2] per element
        let _ = ws;
        debug_assert_eq!(hs.len(), batch * dim);
        debug_assert_eq!(pres.len(), batch * pl);
        debug_assert_eq!(out_f.len(), batch * dim);
        debug_assert_eq!(out_jblk.len(), batch * bl);
        let (u_i, u_f, u_g, u_o) = (self.u(0), self.u(1), self.u(2), self.u(3));
        for i in 0..n {
            let (ui, uf, ug, uo) = (u_i[i], u_f[i], u_g[i], u_o[i]);
            for b in 0..batch {
                let s = &hs[b * dim..(b + 1) * dim];
                let pre = &pres[b * pl..(b + 1) * pl];
                let hi = s[2 * i];
                let ci = s[2 * i + 1];
                let ig = sigmoid(pre[i] + ui * hi);
                let fg = sigmoid(pre[n + i] + uf * hi);
                let gg = (pre[2 * n + i] + ug * hi).tanh();
                let og = sigmoid(pre[3 * n + i] + uo * hi);
                let cp = fg * ci + ig * gg;
                let tc = cp.tanh();
                out_f[b * dim + 2 * i] = og * tc;
                out_f[b * dim + 2 * i + 1] = cp;

                let di = ig * (S::one() - ig);
                let df = fg * (S::one() - fg);
                let dg = S::one() - gg * gg;
                let do_ = og * (S::one() - og);
                let dtc = S::one() - tc * tc;
                let dcp_dh = ci * df * uf + gg * di * ui + ig * dg * ug;
                let dhp_dh = tc * do_ * uo + og * dtc * dcp_dh;
                let blk = &mut out_jblk[b * bl + i * 4..b * bl + (i + 1) * 4];
                blk[0] = dhp_dh;
                blk[1] = og * dtc * fg;
                blk[2] = dcp_dh;
                blk[3] = fg;
            }
        }
    }

    fn flops_step(&self) -> u64 {
        let (n, m) = (self.n as u64, self.m as u64);
        // four input matvecs + elementwise gates/recurrence
        2 * GATES as u64 * n * m + 22 * n
    }

    fn flops_jacobian(&self) -> u64 {
        let n = self.n as u64;
        self.flops_step() + 26 * n
    }
}

impl<S: Scalar> CellGrad<S> for DiagLstm<S> {
    fn num_params(&self) -> usize {
        self.p.len()
    }
    fn params(&self) -> &[S] {
        &self.p
    }
    fn params_mut(&mut self) -> &mut [S] {
        &mut self.p
    }

    fn vjp_step(
        &self,
        s: &[S],
        x: &[S],
        lambda: &[S],
        dh_acc: &mut [S],
        mut dx: Option<&mut [S]>,
        dtheta: &mut [S],
        ws: &mut [S],
    ) {
        let n = self.n;
        let m = self.m;
        self.gates(s, x, None, ws);
        let gv = &ws[..6 * n];

        // pre-activation adjoints per gate; λ read interleaved
        let mut da = vec![S::zero(); GATES * n];
        for i in 0..n {
            let ig = gv[i];
            let fg = gv[n + i];
            let gg = gv[2 * n + i];
            let og = gv[3 * n + i];
            let tc = gv[4 * n + i];
            let dtc = S::one() - tc * tc;
            let lam_h = lambda[2 * i];
            let lam_c = lambda[2 * i + 1];
            let ci = s[2 * i + 1];

            let dcp = lam_c + lam_h * og * dtc;
            da[3 * n + i] = lam_h * tc * (og * (S::one() - og));
            da[n + i] = dcp * ci * (fg * (S::one() - fg));
            da[i] = dcp * gg * (ig * (S::one() - ig));
            da[2 * n + i] = dcp * ig * (S::one() - gg * gg);
            dh_acc[2 * i + 1] += dcp * fg;
        }

        for k in 0..GATES {
            let u = self.u(k);
            let w = self.w(k);
            let (ow, ou, ob) = (self.off_w(k), self.off_u(k), self.off_b(k));
            for i in 0..n {
                let a = da[k * n + i];
                if a == S::zero() {
                    continue;
                }
                let hi = s[2 * i];
                dh_acc[2 * i] += u[i] * a;
                dtheta[ou + i] += a * hi;
                if let Some(dx) = dx.as_deref_mut() {
                    let roww = &w[i * m..(i + 1) * m];
                    for j in 0..m {
                        dx[j] += roww[j] * a;
                    }
                }
                for j in 0..m {
                    dtheta[ow + i * m + j] += a * x[j];
                }
                dtheta[ob + i] += a;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cells::test_support::{check_jacobian, check_vjp};
    use crate::cells::Lstm;

    #[test]
    fn jacobian_matches_fd() {
        let mut rng = Rng::new(51);
        for &(n, m) in &[(1usize, 1usize), (3, 2), (5, 4)] {
            let cell: DiagLstm<f64> = DiagLstm::new(n, m, &mut rng);
            check_jacobian(&cell, 700 + n as u64, 1e-6);
        }
    }

    #[test]
    fn vjp_matches_fd() {
        let mut rng = Rng::new(52);
        let cell: DiagLstm<f64> = DiagLstm::new(3, 2, &mut rng);
        check_vjp(&cell, 800, 1e-6);
    }

    #[test]
    fn structure_reported_block2() {
        let mut rng = Rng::new(53);
        let cell: DiagLstm<f64> = DiagLstm::new(4, 2, &mut rng);
        assert_eq!(cell.jacobian_structure(), JacobianStructure::Block { k: 2 });
        assert_eq!(cell.block_k(), Some(2));
        assert_eq!(cell.state_dim(), 8);
        assert_eq!(cell.num_params(), 4 * (4 * 2 + 2 * 4));
    }

    /// Build the dense [`Lstm`] whose `U_k` are the diagonal embeddings of
    /// this cell's `u_k` (same `W_k` and biases).
    fn dense_twin(cell: &DiagLstm<f64>) -> Lstm<f64> {
        let (n, m) = (cell.n, cell.m);
        let mut p = vec![0.0; GATES * (n * m + n * n + n)];
        p[..GATES * n * m].copy_from_slice(&cell.p[..GATES * n * m]);
        for k in 0..GATES {
            let u = cell.u(k);
            for i in 0..n {
                p[GATES * n * m + k * n * n + i * n + i] = u[i];
            }
        }
        let b_src = &cell.p[GATES * (n * m + n)..];
        p[GATES * (n * m + n * n)..].copy_from_slice(b_src);
        Lstm::from_params(n, m, p)
    }

    /// The diagonal cell IS the dense LSTM with diagonally-embedded
    /// recurrent weights: step and the full dense Jacobian agree, and the
    /// dense Jacobian is exactly block-diagonal.
    #[test]
    fn matches_dense_lstm_with_embedded_diagonal() {
        let mut rng = Rng::new(54);
        for &(n, m) in &[(1usize, 1usize), (3, 2), (5, 3)] {
            let diag: DiagLstm<f64> = DiagLstm::new(n, m, &mut rng);
            let dense = dense_twin(&diag);
            let dim = 2 * n;
            let mut s = vec![0.0; dim];
            let mut x = vec![0.0; m];
            rng.fill_normal(&mut s, 0.8);
            rng.fill_normal(&mut x, 1.0);
            let mut wsd = vec![0.0; diag.ws_len()];
            let mut wsl = vec![0.0; dense.ws_len()];

            let mut f1 = vec![0.0; dim];
            let mut f2 = vec![0.0; dim];
            diag.step(&s, &x, &mut f1, &mut wsd);
            dense.step(&s, &x, &mut f2, &mut wsl);
            assert_eq!(f1, f2, "n={n}: step");

            let mut jf1 = vec![0.0; dim];
            let mut jac1 = vec![0.0; dim * dim];
            diag.jacobian(&s, &x, &mut jf1, &mut jac1, &mut wsd);
            let mut jf2 = vec![0.0; dim];
            let mut jac2 = vec![0.0; dim * dim];
            dense.jacobian(&s, &x, &mut jf2, &mut jac2, &mut wsl);
            assert_eq!(jf1, jf2, "n={n}: jacobian f");
            assert_eq!(jac1, jac2, "n={n}: dense jacobian");
            for r in 0..dim {
                for c in 0..dim {
                    if r / 2 != c / 2 {
                        assert_eq!(jac1[r * dim + c], 0.0, "off-block ({r},{c})");
                    }
                }
            }
        }
    }

    /// Packed Block(2) kernel vs dense emission, and the precomputed-input
    /// paths, all bitwise equal to the direct kernels.
    #[test]
    fn packed_and_pre_paths_match_bitwise() {
        let mut rng = Rng::new(55);
        let (n, m) = (4usize, 3usize);
        let cell: DiagLstm<f64> = DiagLstm::new(n, m, &mut rng);
        let dim = 2 * n;
        let mut s = vec![0.0; dim];
        let mut x = vec![0.0; m];
        rng.fill_normal(&mut s, 0.8);
        rng.fill_normal(&mut x, 1.0);
        let mut ws = vec![0.0; cell.ws_len()];

        let mut f_d = vec![0.0; dim];
        let mut jac = vec![0.0; dim * dim];
        cell.jacobian(&s, &x, &mut f_d, &mut jac, &mut ws);

        let mut f_b = vec![0.0; dim];
        let mut jblk = vec![0.0; dim * 2];
        cell.jacobian_block(&s, &x, &mut f_b, &mut jblk, &mut ws);
        assert_eq!(f_d, f_b);
        for i in 0..n {
            for r in 0..2 {
                for c in 0..2 {
                    assert_eq!(
                        jblk[i * 4 + r * 2 + c],
                        jac[(2 * i + r) * dim + 2 * i + c],
                        "block {i} ({r},{c})"
                    );
                }
            }
        }

        let pl = cell.x_precompute_len();
        let mut pre = vec![0.0; pl];
        cell.precompute_x(&x, &mut pre);
        let mut f_bp = vec![0.0; dim];
        let mut jblk_p = vec![0.0; dim * 2];
        cell.jacobian_block_pre(&s, &pre, &mut f_bp, &mut jblk_p, &mut ws);
        assert_eq!(f_bp, f_b);
        assert_eq!(jblk_p, jblk);
        let mut f_p = vec![0.0; dim];
        let mut jac_p = vec![0.0; dim * dim];
        cell.jacobian_pre(&s, &pre, &mut f_p, &mut jac_p, &mut ws);
        assert_eq!(f_p, f_d);
        assert_eq!(jac_p, jac);
    }

    /// Fused batched kernels vs the looped defaults, bitwise.
    #[test]
    fn batched_kernels_match_looped_bitwise() {
        let mut rng = Rng::new(56);
        let (n, m, batch) = (3usize, 2usize, 4usize);
        let cell: DiagLstm<f64> = DiagLstm::new(n, m, &mut rng);
        let dim = 2 * n;
        let mut hs = vec![0.0; batch * dim];
        let mut xs = vec![0.0; batch * m];
        rng.fill_normal(&mut hs, 0.7);
        rng.fill_normal(&mut xs, 1.0);
        let mut ws = vec![0.0; cell.ws_len()];

        let mut f_b = vec![0.0; batch * dim];
        cell.step_batch(&hs, &xs, &mut f_b, &mut ws, batch);
        let pl = cell.x_precompute_len();
        let mut pres = vec![0.0; batch * pl];
        for s in 0..batch {
            cell.precompute_x(&xs[s * m..(s + 1) * m], &mut pres[s * pl..(s + 1) * pl]);
        }
        let bl = dim * 2;
        let mut jf_b = vec![0.0; batch * dim];
        let mut jb_b = vec![0.0; batch * bl];
        cell.jacobian_pre_block_batch(&hs, &pres, &mut jf_b, &mut jb_b, &mut ws, batch);
        for s in 0..batch {
            let st = &hs[s * dim..(s + 1) * dim];
            let x = &xs[s * m..(s + 1) * m];
            let mut f = vec![0.0; dim];
            cell.step(st, x, &mut f, &mut ws);
            assert_eq!(f, &f_b[s * dim..(s + 1) * dim], "seq {s}: step_batch");
            let mut jf = vec![0.0; dim];
            let mut jb = vec![0.0; bl];
            cell.jacobian_block_pre(st, &pres[s * pl..(s + 1) * pl], &mut jf, &mut jb, &mut ws);
            assert_eq!(jf, &jf_b[s * dim..(s + 1) * dim], "seq {s}: block_batch f");
            assert_eq!(jb, &jb_b[s * bl..(s + 1) * bl], "seq {s}: block_batch blocks");
        }
    }
}
