//! [`DynCell`] — a closed enum over every discrete cell, so one `Model`
//! can stack **heterogeneous** layers (`--cell gru,diag-gru`).
//!
//! `Model<S, C>` is generic over a single cell type; mixing cell kinds per
//! layer therefore needs a sum type rather than trait objects (the
//! [`Cell`]/[`CellGrad`] traits are not object-safe as used — `Model`
//! derives `Clone`, and the executor takes cells by value). Every
//! [`Cell`]/[`CellGrad`] method is delegated **explicitly**, including the
//! defaulted ones: a default body on the enum would erase the per-cell
//! overrides (GRU's fused batched kernels, LSTM/LEM's packed Block(2)
//! kernels, the diagonal cells' structure reports), silently changing
//! kernel dispatch and performance.
//!
//! Single-kind runs keep the concrete static dispatch (`main.rs` only
//! switches to `DynCell` when the `--cell` list has ≥ 2 entries), so the
//! homogeneous hot path pays no enum-matching cost.

use super::{
    Cell, CellGrad, DiagGru, DiagLstm, Elman, Gru, IndRnn, JacobianStructure, Lem, Lstm,
};
use crate::cells::ode_cell::OdeView;
use crate::util::rng::Rng;
use crate::util::scalar::Scalar;

/// A discrete cell of runtime-chosen kind (one variant per concrete cell).
#[derive(Debug, Clone)]
pub enum DynCell<S: Scalar> {
    /// Dense GRU (the paper's main benchmark subject).
    Gru(Gru<S>),
    /// Diagonal-recurrence GRU (natively `Diagonal` Jacobian).
    DiagGru(DiagGru<S>),
    /// Dense LSTM (natural Block(2) pairing).
    Lstm(Lstm<S>),
    /// Diagonal-recurrence LSTM (natively `Block(2)` Jacobian).
    DiagLstm(DiagLstm<S>),
    /// Elman RNN (simplest dense cell).
    Elman(Elman<S>),
    /// IndRNN (element-wise recurrence, natively `Diagonal`).
    IndRnn(IndRnn<S>),
    /// Long Expressive Memory (Block(2) pairing).
    Lem(Lem<S>),
}

/// Delegate an expression to the wrapped concrete cell.
macro_rules! each {
    ($self:ident, $c:ident => $e:expr) => {
        match $self {
            DynCell::Gru($c) => $e,
            DynCell::DiagGru($c) => $e,
            DynCell::Lstm($c) => $e,
            DynCell::DiagLstm($c) => $e,
            DynCell::Elman($c) => $e,
            DynCell::IndRnn($c) => $e,
            DynCell::Lem($c) => $e,
        }
    };
}

impl<S: Scalar> DynCell<S> {
    /// Construct a cell by its `--cell` name (`gru | diag-gru | lstm |
    /// diag-lstm | elman | indrnn | lem`) with `n` states reading `m`
    /// input channels.
    pub fn parse(name: &str, n: usize, m: usize, rng: &mut Rng) -> Result<Self, String> {
        Ok(match name {
            "gru" => DynCell::Gru(Gru::new(n, m, rng)),
            "diag-gru" => DynCell::DiagGru(DiagGru::new(n, m, rng)),
            "lstm" => DynCell::Lstm(Lstm::new(n, m, rng)),
            "diag-lstm" => DynCell::DiagLstm(DiagLstm::new(n, m, rng)),
            "elman" => DynCell::Elman(Elman::new(n, m, rng)),
            "indrnn" => DynCell::IndRnn(IndRnn::new(n, m, rng)),
            "lem" => DynCell::Lem(Lem::new(n, m, rng)),
            other => {
                return Err(format!(
                    "unknown cell {other:?} (gru|diag-gru|lstm|diag-lstm|elman|indrnn|lem)"
                ))
            }
        })
    }

    /// The `--cell` name of the wrapped kind.
    pub fn kind(&self) -> &'static str {
        match self {
            DynCell::Gru(_) => "gru",
            DynCell::DiagGru(_) => "diag-gru",
            DynCell::Lstm(_) => "lstm",
            DynCell::DiagLstm(_) => "diag-lstm",
            DynCell::Elman(_) => "elman",
            DynCell::IndRnn(_) => "indrnn",
            DynCell::Lem(_) => "lem",
        }
    }
}

impl<S: Scalar> Cell<S> for DynCell<S> {
    fn state_dim(&self) -> usize {
        each!(self, c => c.state_dim())
    }
    fn input_dim(&self) -> usize {
        each!(self, c => c.input_dim())
    }
    fn ws_len(&self) -> usize {
        each!(self, c => c.ws_len())
    }
    fn step(&self, h: &[S], x: &[S], out: &mut [S], ws: &mut [S]) {
        each!(self, c => c.step(h, x, out, ws))
    }
    fn jacobian(&self, h: &[S], x: &[S], out_f: &mut [S], out_jac: &mut [S], ws: &mut [S]) {
        each!(self, c => c.jacobian(h, x, out_f, out_jac, ws))
    }
    fn jacobian_structure(&self) -> JacobianStructure {
        each!(self, c => c.jacobian_structure())
    }
    fn block_k(&self) -> Option<usize> {
        each!(self, c => c.block_k())
    }
    fn jacobian_block(&self, h: &[S], x: &[S], out_f: &mut [S], out_jblk: &mut [S], ws: &mut [S]) {
        each!(self, c => c.jacobian_block(h, x, out_f, out_jblk, ws))
    }
    fn jacobian_block_pre(
        &self,
        h: &[S],
        pre: &[S],
        out_f: &mut [S],
        out_jblk: &mut [S],
        ws: &mut [S],
    ) {
        each!(self, c => c.jacobian_block_pre(h, pre, out_f, out_jblk, ws))
    }
    fn jacobian_block_batch(
        &self,
        hs: &[S],
        xs: &[S],
        out_f: &mut [S],
        out_jblk: &mut [S],
        ws: &mut [S],
        batch: usize,
    ) {
        each!(self, c => c.jacobian_block_batch(hs, xs, out_f, out_jblk, ws, batch))
    }
    fn jacobian_pre_block_batch(
        &self,
        hs: &[S],
        pres: &[S],
        out_f: &mut [S],
        out_jblk: &mut [S],
        ws: &mut [S],
        batch: usize,
    ) {
        each!(self, c => c.jacobian_pre_block_batch(hs, pres, out_f, out_jblk, ws, batch))
    }
    fn step_batch(&self, hs: &[S], xs: &[S], out: &mut [S], ws: &mut [S], batch: usize) {
        each!(self, c => c.step_batch(hs, xs, out, ws, batch))
    }
    fn jacobian_batch(
        &self,
        hs: &[S],
        xs: &[S],
        out_f: &mut [S],
        out_jac: &mut [S],
        ws: &mut [S],
        batch: usize,
    ) {
        each!(self, c => c.jacobian_batch(hs, xs, out_f, out_jac, ws, batch))
    }
    fn jacobian_diag_batch(
        &self,
        hs: &[S],
        xs: &[S],
        out_f: &mut [S],
        out_jdiag: &mut [S],
        ws: &mut [S],
        batch: usize,
    ) {
        each!(self, c => c.jacobian_diag_batch(hs, xs, out_f, out_jdiag, ws, batch))
    }
    fn jacobian_pre_batch(
        &self,
        hs: &[S],
        pres: &[S],
        out_f: &mut [S],
        out_jac: &mut [S],
        ws: &mut [S],
        batch: usize,
    ) {
        each!(self, c => c.jacobian_pre_batch(hs, pres, out_f, out_jac, ws, batch))
    }
    fn jacobian_diag_pre_batch(
        &self,
        hs: &[S],
        pres: &[S],
        out_f: &mut [S],
        out_jdiag: &mut [S],
        ws: &mut [S],
        batch: usize,
    ) {
        each!(self, c => c.jacobian_diag_pre_batch(hs, pres, out_f, out_jdiag, ws, batch))
    }
    fn jacobian_diag(&self, h: &[S], x: &[S], out_f: &mut [S], out_jdiag: &mut [S], ws: &mut [S]) {
        each!(self, c => c.jacobian_diag(h, x, out_f, out_jdiag, ws))
    }
    fn jacobian_diag_pre(
        &self,
        h: &[S],
        pre: &[S],
        out_f: &mut [S],
        out_jdiag: &mut [S],
        ws: &mut [S],
    ) {
        each!(self, c => c.jacobian_diag_pre(h, pre, out_f, out_jdiag, ws))
    }
    fn x_precompute_len(&self) -> usize {
        each!(self, c => c.x_precompute_len())
    }
    fn precompute_x(&self, xs: &[S], out: &mut [S]) {
        each!(self, c => c.precompute_x(xs, out))
    }
    fn jacobian_pre(&self, h: &[S], pre: &[S], out_f: &mut [S], out_jac: &mut [S], ws: &mut [S]) {
        each!(self, c => c.jacobian_pre(h, pre, out_f, out_jac, ws))
    }
    fn ode_view(&self) -> Option<OdeView<'_, S>> {
        each!(self, c => c.ode_view())
    }
    fn flops_step(&self) -> u64 {
        each!(self, c => c.flops_step())
    }
    fn flops_jacobian(&self) -> u64 {
        each!(self, c => c.flops_jacobian())
    }
}

impl<S: Scalar> CellGrad<S> for DynCell<S> {
    fn num_params(&self) -> usize {
        each!(self, c => c.num_params())
    }
    fn params(&self) -> &[S] {
        each!(self, c => c.params())
    }
    fn params_mut(&mut self) -> &mut [S] {
        each!(self, c => c.params_mut())
    }
    fn load_params(&mut self, src: &[S]) {
        each!(self, c => c.load_params(src))
    }
    fn vjp_step(
        &self,
        h: &[S],
        x: &[S],
        lambda: &[S],
        dh: &mut [S],
        dx: Option<&mut [S]>,
        dtheta: &mut [S],
        ws: &mut [S],
    ) {
        each!(self, c => c.vjp_step(h, x, lambda, dh, dx, dtheta, ws))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_covers_every_kind_and_rejects_unknown() {
        let mut rng = Rng::new(7);
        for name in ["gru", "diag-gru", "lstm", "diag-lstm", "elman", "indrnn", "lem"] {
            let c: DynCell<f64> = DynCell::parse(name, 4, 3, &mut rng).unwrap();
            assert_eq!(c.kind(), name);
            assert_eq!(c.input_dim(), 3);
            assert!(c.state_dim() == 4 || c.state_dim() == 8, "interleaved cells report 2n");
        }
        assert!(DynCell::<f64>::parse("nope", 4, 3, &mut rng).is_err());
    }

    #[test]
    fn delegation_preserves_overrides_and_values() {
        let mut rng = Rng::new(42);
        let gru: Gru<f64> = Gru::new(3, 2, &mut rng);
        let dyn_gru = DynCell::Gru(gru.clone());
        // structure/precompute overrides survive the wrapper
        assert_eq!(dyn_gru.jacobian_structure(), gru.jacobian_structure());
        assert_eq!(dyn_gru.x_precompute_len(), gru.x_precompute_len());
        assert_eq!(dyn_gru.num_params(), gru.num_params());
        // step values are bitwise identical
        let mut h = vec![0.0; 3];
        let mut x = vec![0.0; 2];
        rng.fill_normal(&mut h, 0.8);
        rng.fill_normal(&mut x, 1.0);
        let mut ws = vec![0.0; gru.ws_len()];
        let (mut a, mut b) = (vec![0.0; 3], vec![0.0; 3]);
        gru.step(&h, &x, &mut a, &mut ws);
        dyn_gru.step(&h, &x, &mut b, &mut ws);
        assert_eq!(a, b);

        let mut rng2 = Rng::new(43);
        let dlstm: DynCell<f64> = DynCell::parse("diag-lstm", 4, 3, &mut rng2).unwrap();
        assert_eq!(dlstm.jacobian_structure(), JacobianStructure::Block { k: 2 });
        assert_eq!(dlstm.block_k(), Some(2));
    }

    #[test]
    fn mixed_stack_chains_dims() {
        use crate::train::native::{Model, Readout};
        let mut rng = Rng::new(11);
        let l0: DynCell<f32> = DynCell::parse("gru", 6, 4, &mut rng).unwrap();
        let l1: DynCell<f32> = DynCell::parse("diag-gru", 5, l0.state_dim(), &mut rng).unwrap();
        let model = Model::stacked(vec![l0, l1], 3, Readout::LastState, &mut rng).unwrap();
        assert_eq!(model.cells().len(), 2);
        assert_eq!(model.cell(0).kind(), "gru");
        assert_eq!(model.cell(1).kind(), "diag-gru");
    }
}
