//! IndRNN (Li et al., CVPR 2018): `h' = tanh(W x + u ⊙ h + b)`.
//!
//! The recurrent weight is a **vector** `u`, so each state unit evolves
//! independently given the input projection. The state Jacobian is exactly
//! diagonal — `∂h'_i/∂h_j = δ_ij (1 − h'_i²) u_i` — which makes IndRNN the
//! natural native carrier of the structured-Jacobian fast path: DEER's
//! INVLIN phase runs entirely through the O(n) kernels of
//! [`crate::scan::diag`], with O(T·n) Jacobian storage instead of O(T·n²).

use super::{init_uniform, Cell, CellGrad, JacobianStructure};
use crate::util::rng::Rng;
use crate::util::scalar::Scalar;

/// IndRNN cell. Parameter layout: `[W (n·m), u (n), b (n)]`.
#[derive(Debug, Clone)]
pub struct IndRnn<S> {
    n: usize,
    m: usize,
    p: Vec<S>,
}

impl<S: Scalar> IndRnn<S> {
    pub fn new(n: usize, m: usize, rng: &mut Rng) -> Self {
        let mut p = vec![S::zero(); n * m + 2 * n];
        init_uniform(&mut p, n, rng);
        // Keep the recurrent gains inside the unit circle at init so long
        // sequences neither blow up nor saturate (Li et al. §3.2).
        let u_lo = n * m;
        for v in p[u_lo..u_lo + n].iter_mut() {
            *v = *v * S::from_f64c(0.9);
        }
        IndRnn { n, m, p }
    }

    pub fn from_params(n: usize, m: usize, p: Vec<S>) -> Self {
        assert_eq!(p.len(), n * m + 2 * n);
        IndRnn { n, m, p }
    }

    fn w(&self) -> &[S] {
        &self.p[..self.n * self.m]
    }
    fn u(&self) -> &[S] {
        &self.p[self.n * self.m..self.n * self.m + self.n]
    }
    fn b(&self) -> &[S] {
        &self.p[self.n * self.m + self.n..]
    }

    /// Pre-activation `W x + u ⊙ h + b` into `out`.
    ///
    /// Accumulation order is `(b + Σ W·x) + u⊙h` — the bias and input
    /// projection first, exactly like [`Cell::precompute_x`], then the
    /// recurrent term — so the direct and precomputed paths are
    /// **bitwise** identical and the DEER driver can mix them freely.
    #[inline]
    fn preact(&self, h: &[S], x: &[S], out: &mut [S]) {
        let (n, m) = (self.n, self.m);
        let (w, u, b) = (self.w(), self.u(), self.b());
        for i in 0..n {
            let mut a = b[i];
            let roww = &w[i * m..(i + 1) * m];
            for j in 0..m {
                a += roww[j] * x[j];
            }
            out[i] = a + u[i] * h[i];
        }
    }
}

impl<S: Scalar> Cell<S> for IndRnn<S> {
    fn state_dim(&self) -> usize {
        self.n
    }
    fn input_dim(&self) -> usize {
        self.m
    }
    fn ws_len(&self) -> usize {
        self.n
    }

    fn jacobian_structure(&self) -> JacobianStructure {
        JacobianStructure::Diagonal
    }

    fn step(&self, h: &[S], x: &[S], out: &mut [S], ws: &mut [S]) {
        self.preact(h, x, ws);
        for i in 0..self.n {
            out[i] = ws[i].tanh();
        }
    }

    fn jacobian(&self, h: &[S], x: &[S], out_f: &mut [S], out_jac: &mut [S], ws: &mut [S]) {
        // Dense emission kept for the generic path: diag embedded in n×n.
        let n = self.n;
        for v in out_jac.iter_mut() {
            *v = S::zero();
        }
        self.preact(h, x, ws);
        let u = self.u();
        for i in 0..n {
            let f = ws[i].tanh();
            out_f[i] = f;
            out_jac[i * n + i] = (S::one() - f * f) * u[i];
        }
    }

    fn jacobian_diag(&self, h: &[S], x: &[S], out_f: &mut [S], out_jdiag: &mut [S], ws: &mut [S]) {
        self.preact(h, x, ws);
        let u = self.u();
        for i in 0..self.n {
            let f = ws[i].tanh();
            out_f[i] = f;
            out_jdiag[i] = (S::one() - f * f) * u[i];
        }
    }

    /// Fused batched step: the unit loop is outermost so each input-weight
    /// row streams across all B elements. Per-element accumulation order is
    /// identical to [`IndRnn::preact`] (bias + input j-loop, then the
    /// recurrent term), so the result is **bitwise** equal to the looped
    /// default.
    fn step_batch(&self, hs: &[S], xs: &[S], out: &mut [S], ws: &mut [S], batch: usize) {
        let (n, m) = (self.n, self.m);
        let _ = ws;
        debug_assert_eq!(hs.len(), batch * n);
        debug_assert_eq!(xs.len(), batch * m);
        debug_assert_eq!(out.len(), batch * n);
        let (w, u, b) = (self.w(), self.u(), self.b());
        for i in 0..n {
            let roww = &w[i * m..(i + 1) * m];
            for s in 0..batch {
                let mut a = b[i];
                let x = &xs[s * m..(s + 1) * m];
                for j in 0..m {
                    a += roww[j] * x[j];
                }
                out[s * n + i] = (a + u[i] * hs[s * n + i]).tanh();
            }
        }
    }

    /// Fused batched packed-diagonal Jacobian — projects each element's
    /// input (identical to [`Cell::precompute_x`], which matches the
    /// direct [`IndRnn::preact`] order bitwise) and delegates to the fused
    /// [`Cell::jacobian_diag_pre_batch`] kernel. Not a hot path — FUNCEVAL
    /// hoists the projections and calls the pre kernel directly — so the
    /// scratch allocation is fine.
    fn jacobian_diag_batch(
        &self,
        hs: &[S],
        xs: &[S],
        out_f: &mut [S],
        out_jdiag: &mut [S],
        ws: &mut [S],
        batch: usize,
    ) {
        let (n, m) = (self.n, self.m);
        debug_assert_eq!(xs.len(), batch * m);
        let mut pres = vec![S::zero(); batch * n];
        for s in 0..batch {
            self.precompute_x(&xs[s * m..(s + 1) * m], &mut pres[s * n..(s + 1) * n]);
        }
        self.jacobian_diag_pre_batch(hs, &pres, out_f, out_jdiag, ws, batch);
    }

    /// Fused batched [`Cell::jacobian_diag_pre`] — the FUNCEVAL hot kernel
    /// of the natively-diagonal path: the recurrence is elementwise, so the
    /// unit loop is outermost and each `u[i]` streams across all B
    /// elements. Per-element arithmetic is identical to the looped default,
    /// hence **bitwise** equal — the driver's fused-vs-per-element dispatch
    /// never changes numerics.
    fn jacobian_diag_pre_batch(
        &self,
        hs: &[S],
        pres: &[S],
        out_f: &mut [S],
        out_jdiag: &mut [S],
        ws: &mut [S],
        batch: usize,
    ) {
        let n = self.n;
        let _ = ws;
        debug_assert_eq!(hs.len(), batch * n);
        debug_assert_eq!(pres.len(), batch * n);
        debug_assert_eq!(out_f.len(), batch * n);
        debug_assert_eq!(out_jdiag.len(), batch * n);
        let u = self.u();
        for i in 0..n {
            let ui = u[i];
            for s in 0..batch {
                let f = (pres[s * n + i] + ui * hs[s * n + i]).tanh();
                out_f[s * n + i] = f;
                out_jdiag[s * n + i] = (S::one() - f * f) * ui;
            }
        }
    }

    fn x_precompute_len(&self) -> usize {
        self.n
    }

    /// `out[i] = W x_i + b` — everything independent of the trajectory guess.
    fn precompute_x(&self, xs: &[S], out: &mut [S]) {
        let (n, m) = (self.n, self.m);
        let t_len = xs.len() / m;
        debug_assert_eq!(out.len(), t_len * n);
        let (w, b) = (self.w(), self.b());
        for t in 0..t_len {
            let x = &xs[t * m..(t + 1) * m];
            let o = &mut out[t * n..(t + 1) * n];
            for i in 0..n {
                let mut a = b[i];
                let roww = &w[i * m..(i + 1) * m];
                for j in 0..m {
                    a += roww[j] * x[j];
                }
                o[i] = a;
            }
        }
    }

    fn jacobian_pre(&self, h: &[S], pre: &[S], out_f: &mut [S], out_jac: &mut [S], ws: &mut [S]) {
        let n = self.n;
        let _ = ws;
        for v in out_jac.iter_mut() {
            *v = S::zero();
        }
        let u = self.u();
        for i in 0..n {
            let f = (pre[i] + u[i] * h[i]).tanh();
            out_f[i] = f;
            out_jac[i * n + i] = (S::one() - f * f) * u[i];
        }
    }

    fn jacobian_diag_pre(
        &self,
        h: &[S],
        pre: &[S],
        out_f: &mut [S],
        out_jdiag: &mut [S],
        ws: &mut [S],
    ) {
        let _ = ws;
        let u = self.u();
        for i in 0..self.n {
            let f = (pre[i] + u[i] * h[i]).tanh();
            out_f[i] = f;
            out_jdiag[i] = (S::one() - f * f) * u[i];
        }
    }

    fn flops_step(&self) -> u64 {
        let (n, m) = (self.n as u64, self.m as u64);
        2 * n * m + 4 * n
    }

    fn flops_jacobian(&self) -> u64 {
        let n = self.n as u64;
        self.flops_step() + 3 * n
    }
}

impl<S: Scalar> CellGrad<S> for IndRnn<S> {
    fn num_params(&self) -> usize {
        self.p.len()
    }
    fn params(&self) -> &[S] {
        &self.p
    }
    fn params_mut(&mut self) -> &mut [S] {
        &mut self.p
    }

    fn vjp_step(
        &self,
        h: &[S],
        x: &[S],
        lambda: &[S],
        dh: &mut [S],
        mut dx: Option<&mut [S]>,
        dtheta: &mut [S],
        ws: &mut [S],
    ) {
        let (n, m) = (self.n, self.m);
        self.preact(h, x, ws);
        let u = self.u();
        let w = self.w();
        let off_u = n * m;
        let off_b = n * m + n;
        for i in 0..n {
            let f = ws[i].tanh();
            let da = lambda[i] * (S::one() - f * f);
            dh[i] += u[i] * da;
            dtheta[off_u + i] += da * h[i];
            if let Some(dx) = dx.as_deref_mut() {
                let roww = &w[i * m..(i + 1) * m];
                for j in 0..m {
                    dx[j] += roww[j] * da;
                }
            }
            for j in 0..m {
                dtheta[i * m + j] += da * x[j];
            }
            dtheta[off_b + i] += da;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cells::test_support::{check_jacobian, check_vjp};

    #[test]
    fn jacobian_matches_fd() {
        let mut rng = Rng::new(13);
        for &(n, m) in &[(1usize, 1usize), (3, 2), (6, 4)] {
            let cell: IndRnn<f64> = IndRnn::new(n, m, &mut rng);
            check_jacobian(&cell, 300 + n as u64, 1e-7);
        }
    }

    #[test]
    fn vjp_matches_fd() {
        let mut rng = Rng::new(14);
        let cell: IndRnn<f64> = IndRnn::new(4, 3, &mut rng);
        check_vjp(&cell, 88, 1e-6);
    }

    #[test]
    fn packed_diag_matches_dense_jacobian() {
        let mut rng = Rng::new(15);
        let (n, m) = (5usize, 3usize);
        let cell: IndRnn<f64> = IndRnn::new(n, m, &mut rng);
        let mut h = vec![0.0; n];
        let mut x = vec![0.0; m];
        rng.fill_normal(&mut h, 0.8);
        rng.fill_normal(&mut x, 1.0);
        let mut ws = vec![0.0; cell.ws_len()];

        let mut f_dense = vec![0.0; n];
        let mut jac = vec![0.0; n * n];
        cell.jacobian(&h, &x, &mut f_dense, &mut jac, &mut ws);

        let mut f_diag = vec![0.0; n];
        let mut jd = vec![0.0; n];
        cell.jacobian_diag(&h, &x, &mut f_diag, &mut jd, &mut ws);

        for i in 0..n {
            assert!((f_dense[i] - f_diag[i]).abs() < 1e-15);
            assert!((jac[i * n + i] - jd[i]).abs() < 1e-15);
            for j in 0..n {
                if i != j {
                    assert_eq!(jac[i * n + j], 0.0, "off-diagonal {i},{j} non-zero");
                }
            }
        }
    }

    #[test]
    fn precompute_paths_match_direct() {
        let mut rng = Rng::new(16);
        let (n, m, t) = (4usize, 2usize, 9usize);
        let cell: IndRnn<f64> = IndRnn::new(n, m, &mut rng);
        let mut xs = vec![0.0; t * m];
        rng.fill_normal(&mut xs, 1.0);
        let mut pre = vec![0.0; t * n];
        cell.precompute_x(&xs, &mut pre);

        let mut h = vec![0.0; n];
        rng.fill_normal(&mut h, 0.5);
        let mut ws = vec![0.0; cell.ws_len()];
        for i in 0..t {
            let x = &xs[i * m..(i + 1) * m];
            let p = &pre[i * n..(i + 1) * n];
            let (mut f1, mut f2) = (vec![0.0; n], vec![0.0; n]);
            let (mut d1, mut d2) = (vec![0.0; n], vec![0.0; n]);
            cell.jacobian_diag(&h, x, &mut f1, &mut d1, &mut ws);
            cell.jacobian_diag_pre(&h, p, &mut f2, &mut d2, &mut ws);
            for j in 0..n {
                assert!((f1[j] - f2[j]).abs() < 1e-14);
                assert!((d1[j] - d2[j]).abs() < 1e-14);
            }
        }
    }

    #[test]
    fn structure_reported_diagonal() {
        let mut rng = Rng::new(17);
        let cell: IndRnn<f64> = IndRnn::new(2, 2, &mut rng);
        assert_eq!(cell.jacobian_structure(), JacobianStructure::Diagonal);
        assert_eq!(JacobianStructure::Diagonal.jac_len(7), 7);
        assert_eq!(JacobianStructure::Dense.jac_len(7), 49);
    }
}
