//! Gated Recurrent Unit (Cho et al., 2014) — the paper's primary benchmark
//! cell (Fig. 2/3, Tables 4–6, the EigenWorms classifier of §4.3).
//!
//! Equations (PyTorch/flax convention):
//!
//! ```text
//! r  = σ(W_ir x + b_ir + W_hr h + b_hr)
//! z  = σ(W_iz x + b_iz + W_hz h + b_hz)
//! m  = W_hn h + b_hn
//! ñ  = tanh(W_in x + b_in + r ⊙ m)
//! h' = (1 − z) ⊙ ñ + z ⊙ h
//! ```
//!
//! Analytic state Jacobian (used for DEER's `G = −∂f/∂h`):
//!
//! ```text
//! ∂h'/∂h = diag(1−z)·diag(1−ñ²)·[diag(r)·W_hn + diag(m)·diag(r(1−r))·W_hr]
//!        + diag(h−ñ)·diag(z(1−z))·W_hz + diag(z)
//! ```

use super::{init_uniform, sigmoid, Cell, CellGrad};
use crate::util::rng::Rng;
use crate::util::scalar::Scalar;

/// GRU cell with a flat parameter vector.
///
/// Layout: `[W_ir, W_iz, W_in] (3·n·m)`, `[W_hr, W_hz, W_hn] (3·n·n)`,
/// `[b_ir, b_iz, b_in, b_hr, b_hz, b_hn] (6·n)`.
#[derive(Debug, Clone)]
pub struct Gru<S> {
    n: usize,
    m: usize,
    p: Vec<S>,
}

// Workspace layout offsets (ws_len = 6n):
// r (n) | z (n) | mgate (n) | nh (n) | tmp (n) | tmp2 (n)

impl<S: Scalar> Gru<S> {
    /// New GRU with `n` hidden units and `m` inputs, uniform(-1/√n) init.
    pub fn new(n: usize, m: usize, rng: &mut Rng) -> Self {
        let mut p = vec![S::zero(); 3 * n * m + 3 * n * n + 6 * n];
        init_uniform(&mut p, n, rng);
        Gru { n, m, p }
    }

    /// Construct from an existing flat parameter vector.
    pub fn from_params(n: usize, m: usize, p: Vec<S>) -> Self {
        assert_eq!(p.len(), 3 * n * m + 3 * n * n + 6 * n);
        Gru { n, m, p }
    }

    #[inline]
    fn w_i(&self, k: usize) -> &[S] {
        let (n, m) = (self.n, self.m);
        &self.p[k * n * m..(k + 1) * n * m]
    }
    #[inline]
    fn w_h(&self, k: usize) -> &[S] {
        let (n, m) = (self.n, self.m);
        let base = 3 * n * m;
        &self.p[base + k * n * n..base + (k + 1) * n * n]
    }
    #[inline]
    fn b(&self, k: usize) -> &[S] {
        let (n, m) = (self.n, self.m);
        let base = 3 * n * m + 3 * n * n;
        &self.p[base + k * n..base + (k + 1) * n]
    }
    fn off_w_i(&self, k: usize) -> usize {
        k * self.n * self.m
    }
    fn off_w_h(&self, k: usize) -> usize {
        3 * self.n * self.m + k * self.n * self.n
    }
    fn off_b(&self, k: usize) -> usize {
        3 * self.n * self.m + 3 * self.n * self.n + k * self.n
    }

    /// Compute gate pre-activations and activations into ws.
    /// After this: ws = [r, z, m, ñ, .., ..].
    #[inline]
    fn gates(&self, h: &[S], x: &[S], ws: &mut [S]) {
        let n = self.n;
        let m = self.m;
        let (r_s, rest) = ws.split_at_mut(n);
        let (z_s, rest) = rest.split_at_mut(n);
        let (m_s, rest) = rest.split_at_mut(n);
        let (nh_s, _) = rest.split_at_mut(n);

        let (w_ir, w_iz, w_in) = (self.w_i(0), self.w_i(1), self.w_i(2));
        let (w_hr, w_hz, w_hn) = (self.w_h(0), self.w_h(1), self.w_h(2));
        let (b_ir, b_iz, b_in) = (self.b(0), self.b(1), self.b(2));
        let (b_hr, b_hz, b_hn) = (self.b(3), self.b(4), self.b(5));

        for i in 0..n {
            // input contributions
            let mut ar = b_ir[i] + b_hr[i];
            let mut az = b_iz[i] + b_hz[i];
            let mut an = b_in[i];
            let (rowr, rowz, rown) = (&w_ir[i * m..(i + 1) * m], &w_iz[i * m..(i + 1) * m], &w_in[i * m..(i + 1) * m]);
            for j in 0..m {
                let xj = x[j];
                ar += rowr[j] * xj;
                az += rowz[j] * xj;
                an += rown[j] * xj;
            }
            // hidden contributions
            let mut hr = S::zero();
            let mut hz = S::zero();
            let mut hm = b_hn[i];
            let (rowhr, rowhz, rowhn) =
                (&w_hr[i * n..(i + 1) * n], &w_hz[i * n..(i + 1) * n], &w_hn[i * n..(i + 1) * n]);
            for j in 0..n {
                let hj = h[j];
                hr += rowhr[j] * hj;
                hz += rowhz[j] * hj;
                hm += rowhn[j] * hj;
            }
            let r = sigmoid(ar + hr);
            let z = sigmoid(az + hz);
            r_s[i] = r;
            z_s[i] = z;
            m_s[i] = hm;
            nh_s[i] = (an + r * hm).tanh();
        }
    }
}

impl<S: Scalar> Gru<S> {
    /// Gate computation from precomputed input projections
    /// `pre = [a_r_x, a_z_x, a_n_x]` (3n per step); hidden matvecs only.
    #[inline]
    fn gates_pre(&self, h: &[S], pre: &[S], ws: &mut [S]) {
        let n = self.n;
        let (w_hr, w_hz, w_hn) = (self.w_h(0), self.w_h(1), self.w_h(2));
        let b_hn = self.b(5);
        for i in 0..n {
            let mut hr = S::zero();
            let mut hz = S::zero();
            let mut hm = b_hn[i];
            let (rowhr, rowhz, rowhn) =
                (&w_hr[i * n..(i + 1) * n], &w_hz[i * n..(i + 1) * n], &w_hn[i * n..(i + 1) * n]);
            for j in 0..n {
                let hj = h[j];
                hr += rowhr[j] * hj;
                hz += rowhz[j] * hj;
                hm += rowhn[j] * hj;
            }
            let r = sigmoid(pre[i] + hr);
            let z = sigmoid(pre[n + i] + hz);
            ws[i] = r;
            ws[n + i] = z;
            ws[2 * n + i] = hm;
            ws[3 * n + i] = (pre[2 * n + i] + r * hm).tanh();
        }
    }
}

impl<S: Scalar> Cell<S> for Gru<S> {
    fn x_precompute_len(&self) -> usize {
        3 * self.n
    }

    /// `out[i] = [W_ir x_i + b_ir + b_hr, W_iz x_i + b_iz + b_hz,
    /// W_in x_i + b_in]` — everything that is independent of the trajectory
    /// guess, computed once per DEER evaluation (§Perf).
    fn precompute_x(&self, xs: &[S], out: &mut [S]) {
        let n = self.n;
        let m = self.m;
        let t_len = xs.len() / m;
        debug_assert_eq!(out.len(), t_len * 3 * n);
        let (w_ir, w_iz, w_in) = (self.w_i(0), self.w_i(1), self.w_i(2));
        let (b_ir, b_iz, b_in) = (self.b(0), self.b(1), self.b(2));
        let (b_hr, b_hz) = (self.b(3), self.b(4));
        for t in 0..t_len {
            let x = &xs[t * m..(t + 1) * m];
            let o = &mut out[t * 3 * n..(t + 1) * 3 * n];
            for i in 0..n {
                let mut ar = b_ir[i] + b_hr[i];
                let mut az = b_iz[i] + b_hz[i];
                let mut an = b_in[i];
                let (rowr, rowz, rown) =
                    (&w_ir[i * m..(i + 1) * m], &w_iz[i * m..(i + 1) * m], &w_in[i * m..(i + 1) * m]);
                for j in 0..m {
                    let xj = x[j];
                    ar += rowr[j] * xj;
                    az += rowz[j] * xj;
                    an += rown[j] * xj;
                }
                o[i] = ar;
                o[n + i] = az;
                o[2 * n + i] = an;
            }
        }
    }

    fn jacobian_pre(&self, h: &[S], pre: &[S], out_f: &mut [S], out_jac: &mut [S], ws: &mut [S]) {
        let n = self.n;
        self.gates_pre(h, pre, ws);
        let (w_hr, w_hz, w_hn) = (self.w_h(0), self.w_h(1), self.w_h(2));
        for i in 0..n {
            let r = ws[i];
            let z = ws[n + i];
            let mg = ws[2 * n + i];
            let nh = ws[3 * n + i];
            out_f[i] = (S::one() - z) * nh + z * h[i];
            let dn = S::one() - nh * nh;
            let dr = r * (S::one() - r);
            let dz = z * (S::one() - z);
            let c1 = (S::one() - z) * dn * r;
            let c2 = (S::one() - z) * dn * mg * dr;
            let c3 = (h[i] - nh) * dz;
            let (rowhr, rowhz, rowhn) =
                (&w_hr[i * n..(i + 1) * n], &w_hz[i * n..(i + 1) * n], &w_hn[i * n..(i + 1) * n]);
            let jrow = &mut out_jac[i * n..(i + 1) * n];
            for j in 0..n {
                jrow[j] = c1 * rowhn[j] + c2 * rowhr[j] + c3 * rowhz[j];
            }
            jrow[i] += z;
        }
    }

    fn state_dim(&self) -> usize {
        self.n
    }
    fn input_dim(&self) -> usize {
        self.m
    }
    fn ws_len(&self) -> usize {
        6 * self.n
    }

    /// Fused batched step: the batch axis is folded into the gate matmuls —
    /// the unit loop is outermost so each weight row (`W_i*[i]`, `W_h*[i]`)
    /// is loaded once and streamed across all B elements instead of being
    /// re-fetched B times. Per-element accumulation order is identical to
    /// [`Gru::gates`] (biases, then the input j-loop, then the hidden
    /// j-loop), so the result is **bitwise** equal to the looped default.
    fn step_batch(&self, hs: &[S], xs: &[S], out: &mut [S], ws: &mut [S], batch: usize) {
        let n = self.n;
        let m = self.m;
        let _ = ws;
        debug_assert_eq!(hs.len(), batch * n);
        debug_assert_eq!(xs.len(), batch * m);
        debug_assert_eq!(out.len(), batch * n);
        let (w_ir, w_iz, w_in) = (self.w_i(0), self.w_i(1), self.w_i(2));
        let (w_hr, w_hz, w_hn) = (self.w_h(0), self.w_h(1), self.w_h(2));
        let (b_ir, b_iz, b_in) = (self.b(0), self.b(1), self.b(2));
        let (b_hr, b_hz, b_hn) = (self.b(3), self.b(4), self.b(5));
        for i in 0..n {
            let (rowr, rowz, rown) =
                (&w_ir[i * m..(i + 1) * m], &w_iz[i * m..(i + 1) * m], &w_in[i * m..(i + 1) * m]);
            let (rowhr, rowhz, rowhn) =
                (&w_hr[i * n..(i + 1) * n], &w_hz[i * n..(i + 1) * n], &w_hn[i * n..(i + 1) * n]);
            for s in 0..batch {
                let h = &hs[s * n..(s + 1) * n];
                let x = &xs[s * m..(s + 1) * m];
                let mut ar = b_ir[i] + b_hr[i];
                let mut az = b_iz[i] + b_hz[i];
                let mut an = b_in[i];
                for j in 0..m {
                    let xj = x[j];
                    ar += rowr[j] * xj;
                    az += rowz[j] * xj;
                    an += rown[j] * xj;
                }
                let mut hr = S::zero();
                let mut hz = S::zero();
                let mut hm = b_hn[i];
                for j in 0..n {
                    let hj = h[j];
                    hr += rowhr[j] * hj;
                    hz += rowhz[j] * hj;
                    hm += rowhn[j] * hj;
                }
                let r = sigmoid(ar + hr);
                let z = sigmoid(az + hz);
                let nh = (an + r * hm).tanh();
                out[s * n + i] = (S::one() - z) * nh + z * h[i];
            }
        }
    }

    /// Fused batched `jacobian` — projects each element's input (the same
    /// accumulation order as [`Cell::precompute_x`], which matches the
    /// direct gate path bitwise) and delegates to the fused
    /// [`Cell::jacobian_pre_batch`] kernel, so the gate math lives in one
    /// place. Not a hot path (FUNCEVAL hoists the projections and calls
    /// the pre kernel directly), hence the scratch allocation is fine.
    fn jacobian_batch(
        &self,
        hs: &[S],
        xs: &[S],
        out_f: &mut [S],
        out_jac: &mut [S],
        ws: &mut [S],
        batch: usize,
    ) {
        let m = self.m;
        let pl = 3 * self.n;
        debug_assert_eq!(xs.len(), batch * m);
        let mut pres = vec![S::zero(); batch * pl];
        for s in 0..batch {
            self.precompute_x(&xs[s * m..(s + 1) * m], &mut pres[s * pl..(s + 1) * pl]);
        }
        self.jacobian_pre_batch(hs, &pres, out_f, out_jac, ws, batch);
    }

    /// Fused batched [`Cell::jacobian_pre`] — the FUNCEVAL hot kernel:
    /// the unit loop is outermost so each recurrent weight row (`W_h*[i]`)
    /// is loaded once and streamed across all B elements instead of being
    /// re-fetched B times. Per-element accumulation order is identical to
    /// [`Gru::gates_pre`] / [`Cell::jacobian_pre`], so the result is
    /// **bitwise** equal to the looped default — the driver's fused-vs-
    /// per-element dispatch never changes numerics.
    fn jacobian_pre_batch(
        &self,
        hs: &[S],
        pres: &[S],
        out_f: &mut [S],
        out_jac: &mut [S],
        ws: &mut [S],
        batch: usize,
    ) {
        let n = self.n;
        let _ = ws;
        debug_assert_eq!(hs.len(), batch * n);
        debug_assert_eq!(pres.len(), batch * 3 * n);
        debug_assert_eq!(out_f.len(), batch * n);
        debug_assert_eq!(out_jac.len(), batch * n * n);
        let (w_hr, w_hz, w_hn) = (self.w_h(0), self.w_h(1), self.w_h(2));
        let b_hn = self.b(5);
        for i in 0..n {
            let (rowhr, rowhz, rowhn) =
                (&w_hr[i * n..(i + 1) * n], &w_hz[i * n..(i + 1) * n], &w_hn[i * n..(i + 1) * n]);
            for s in 0..batch {
                let h = &hs[s * n..(s + 1) * n];
                let pre = &pres[s * 3 * n..(s + 1) * 3 * n];
                let mut hr = S::zero();
                let mut hz = S::zero();
                let mut hm = b_hn[i];
                for j in 0..n {
                    let hj = h[j];
                    hr += rowhr[j] * hj;
                    hz += rowhz[j] * hj;
                    hm += rowhn[j] * hj;
                }
                let r = sigmoid(pre[i] + hr);
                let z = sigmoid(pre[n + i] + hz);
                let mg = hm;
                let nh = (pre[2 * n + i] + r * hm).tanh();
                out_f[s * n + i] = (S::one() - z) * nh + z * h[i];

                let dn = S::one() - nh * nh;
                let dr = r * (S::one() - r);
                let dz = z * (S::one() - z);
                let c1 = (S::one() - z) * dn * r;
                let c2 = (S::one() - z) * dn * mg * dr;
                let c3 = (h[i] - nh) * dz;
                let jrow = &mut out_jac[s * n * n + i * n..s * n * n + (i + 1) * n];
                for j in 0..n {
                    jrow[j] = c1 * rowhn[j] + c2 * rowhr[j] + c3 * rowhz[j];
                }
                jrow[i] += z;
            }
        }
    }

    fn step(&self, h: &[S], x: &[S], out: &mut [S], ws: &mut [S]) {
        let n = self.n;
        self.gates(h, x, ws);
        for i in 0..n {
            let (r_, z, nh) = (ws[i], ws[n + i], ws[3 * n + i]);
            let _ = r_;
            out[i] = (S::one() - z) * nh + z * h[i];
        }
    }

    fn jacobian(&self, h: &[S], x: &[S], out_f: &mut [S], out_jac: &mut [S], ws: &mut [S]) {
        let n = self.n;
        self.gates(h, x, ws);
        let (w_hr, w_hz, w_hn) = (self.w_h(0), self.w_h(1), self.w_h(2));
        for i in 0..n {
            let r = ws[i];
            let z = ws[n + i];
            let mg = ws[2 * n + i];
            let nh = ws[3 * n + i];
            out_f[i] = (S::one() - z) * nh + z * h[i];

            let dn = S::one() - nh * nh; // tanh'
            let dr = r * (S::one() - r);
            let dz = z * (S::one() - z);
            let c1 = (S::one() - z) * dn * r; // coeff of W_hn
            let c2 = (S::one() - z) * dn * mg * dr; // coeff of W_hr
            let c3 = (h[i] - nh) * dz; // coeff of W_hz
            let (rowhr, rowhz, rowhn) =
                (&w_hr[i * n..(i + 1) * n], &w_hz[i * n..(i + 1) * n], &w_hn[i * n..(i + 1) * n]);
            let jrow = &mut out_jac[i * n..(i + 1) * n];
            for j in 0..n {
                jrow[j] = c1 * rowhn[j] + c2 * rowhr[j] + c3 * rowhz[j];
            }
            jrow[i] += z;
        }
    }

    fn flops_step(&self) -> u64 {
        let n = self.n as u64;
        let m = self.m as u64;
        // three input matvecs + three hidden matvecs + elementwise
        2 * 3 * n * (n + m) + 12 * n
    }

    fn flops_jacobian(&self) -> u64 {
        let n = self.n as u64;
        self.flops_step() + 3 * n * n + 10 * n
    }
}

impl<S: Scalar> CellGrad<S> for Gru<S> {
    fn num_params(&self) -> usize {
        self.p.len()
    }
    fn params(&self) -> &[S] {
        &self.p
    }
    fn params_mut(&mut self) -> &mut [S] {
        &mut self.p
    }

    fn vjp_step(
        &self,
        h: &[S],
        x: &[S],
        lambda: &[S],
        dh: &mut [S],
        mut dx: Option<&mut [S]>,
        dtheta: &mut [S],
        ws: &mut [S],
    ) {
        let n = self.n;
        let m = self.m;
        self.gates(h, x, ws);

        // per-unit adjoints
        // da_r, da_z: pre-activation adjoints of r and z gates
        // dc: adjoint of the tanh pre-activation's input part (== d b_in)
        // dm: adjoint of m = W_hn h + b_hn
        let mut da_r = vec![S::zero(); n];
        let mut da_z = vec![S::zero(); n];
        let mut dc = vec![S::zero(); n];
        let mut dm = vec![S::zero(); n];
        for i in 0..n {
            let r = ws[i];
            let z = ws[n + i];
            let mg = ws[2 * n + i];
            let nh = ws[3 * n + i];
            let lam = lambda[i];
            // h' = (1−z)ñ + z h
            dh[i] += lam * z;
            let dnh = lam * (S::one() - z);
            let dzg = lam * (h[i] - nh);
            let du = dnh * (S::one() - nh * nh); // pre-tanh
            dc[i] = du;
            dm[i] = du * r;
            da_r[i] = du * mg * (r * (S::one() - r));
            da_z[i] = dzg * (z * (S::one() - z));
        }

        let (w_hr, w_hz, w_hn) = (self.w_h(0), self.w_h(1), self.w_h(2));
        // dh += W_hrᵀ da_r + W_hzᵀ da_z + W_hnᵀ dm
        for i in 0..n {
            let (ar, az, am) = (da_r[i], da_z[i], dm[i]);
            let (rowhr, rowhz, rowhn) =
                (&w_hr[i * n..(i + 1) * n], &w_hz[i * n..(i + 1) * n], &w_hn[i * n..(i + 1) * n]);
            for j in 0..n {
                dh[j] += rowhr[j] * ar + rowhz[j] * az + rowhn[j] * am;
            }
        }

        // dx += W_irᵀ da_r + W_izᵀ da_z + W_inᵀ dc
        if let Some(dx) = dx.as_deref_mut() {
            let (w_ir, w_iz, w_in) = (self.w_i(0), self.w_i(1), self.w_i(2));
            for i in 0..n {
                let (ar, az, ac) = (da_r[i], da_z[i], dc[i]);
                let (rowir, rowiz, rowin) =
                    (&w_ir[i * m..(i + 1) * m], &w_iz[i * m..(i + 1) * m], &w_in[i * m..(i + 1) * m]);
                for j in 0..m {
                    dx[j] += rowir[j] * ar + rowiz[j] * az + rowin[j] * ac;
                }
            }
        }

        // parameter gradients
        let (o_wir, o_wiz, o_win) = (self.off_w_i(0), self.off_w_i(1), self.off_w_i(2));
        let (o_whr, o_whz, o_whn) = (self.off_w_h(0), self.off_w_h(1), self.off_w_h(2));
        for i in 0..n {
            let (ar, az, ac, am) = (da_r[i], da_z[i], dc[i], dm[i]);
            for j in 0..m {
                let xj = x[j];
                dtheta[o_wir + i * m + j] += ar * xj;
                dtheta[o_wiz + i * m + j] += az * xj;
                dtheta[o_win + i * m + j] += ac * xj;
            }
            for j in 0..n {
                let hj = h[j];
                dtheta[o_whr + i * n + j] += ar * hj;
                dtheta[o_whz + i * n + j] += az * hj;
                dtheta[o_whn + i * n + j] += am * hj;
            }
            dtheta[self.off_b(0) + i] += ar; // b_ir
            dtheta[self.off_b(1) + i] += az; // b_iz
            dtheta[self.off_b(2) + i] += ac; // b_in
            dtheta[self.off_b(3) + i] += ar; // b_hr
            dtheta[self.off_b(4) + i] += az; // b_hz
            dtheta[self.off_b(5) + i] += am; // b_hn
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cells::test_support::{check_jacobian, check_vjp};

    #[test]
    fn jacobian_matches_fd() {
        let mut rng = Rng::new(11);
        for &(n, m) in &[(1usize, 1usize), (2, 3), (4, 4), (8, 2)] {
            let cell: Gru<f64> = Gru::new(n, m, &mut rng);
            check_jacobian(&cell, 100 + n as u64, 1e-6);
        }
    }

    #[test]
    fn vjp_matches_fd() {
        let mut rng = Rng::new(21);
        for &(n, m) in &[(1usize, 2usize), (3, 3), (6, 4)] {
            let cell: Gru<f64> = Gru::new(n, m, &mut rng);
            check_vjp(&cell, 200 + n as u64, 1e-6);
        }
    }

    #[test]
    fn zero_state_zero_input_fixed_point_structure() {
        // With all-zero params, r=z=1/2, ñ=0 → h' = h/2.
        let cell: Gru<f64> = Gru::from_params(3, 2, vec![0.0; 3 * 3 * 2 + 3 * 9 + 18]);
        let h = vec![1.0, -2.0, 0.5];
        let mut out = vec![0.0; 3];
        let mut ws = vec![0.0; cell.ws_len()];
        cell.step(&h, &[0.0, 0.0], &mut out, &mut ws);
        for (o, hi) in out.iter().zip(h.iter()) {
            assert!((o - hi / 2.0).abs() < 1e-14);
        }
    }

    #[test]
    fn f32_matches_f64() {
        let mut rng = Rng::new(5);
        let c64: Gru<f64> = Gru::new(4, 3, &mut rng);
        let p32: Vec<f32> = c64.params().iter().map(|&v| v as f32).collect();
        let c32: Gru<f32> = Gru::from_params(4, 3, p32);
        let h64 = vec![0.1, -0.2, 0.3, 0.4];
        let x64 = vec![1.0, 0.5, -1.0];
        let h32: Vec<f32> = h64.iter().map(|&v| v as f32).collect();
        let x32: Vec<f32> = x64.iter().map(|&v| v as f32).collect();
        let mut o64 = vec![0.0f64; 4];
        let mut o32 = vec![0.0f32; 4];
        let mut w64 = vec![0.0f64; c64.ws_len()];
        let mut w32 = vec![0.0f32; c32.ws_len()];
        c64.step(&h64, &x64, &mut o64, &mut w64);
        c32.step(&h32, &x32, &mut o32, &mut w32);
        for (a, b) in o64.iter().zip(o32.iter()) {
            assert!((a - *b as f64).abs() < 1e-6);
        }
    }

    #[test]
    fn param_count() {
        let mut rng = Rng::new(1);
        let c: Gru<f64> = Gru::new(5, 3, &mut rng);
        assert_eq!(c.num_params(), 3 * 5 * 3 + 3 * 25 + 30);
    }

    #[test]
    fn bounded_output() {
        // GRU state stays bounded for bounded init: |h'| ≤ max(|h|, 1).
        let mut rng = Rng::new(33);
        let c: Gru<f64> = Gru::new(8, 4, &mut rng);
        let mut h = vec![0.0; 8];
        let mut x = vec![0.0; 4];
        let mut ws = vec![0.0; c.ws_len()];
        let mut out = vec![0.0; 8];
        for step in 0..200 {
            rng.fill_normal(&mut x, 1.0);
            c.step(&h, &x, &mut out, &mut ws);
            std::mem::swap(&mut h, &mut out);
            let mx = h.iter().fold(0.0f64, |a, &b| a.max(b.abs()));
            assert!(mx <= 1.0 + 1e-12, "step {step}: |h|∞ = {mx}");
        }
    }
}
