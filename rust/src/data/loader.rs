//! Dataset container, splits and batch iteration.

use crate::util::rng::Rng;

/// Split kind (the paper uses 70/15/15 for EigenWorms, App. B.3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Split {
    Train,
    Val,
    Test,
}

/// An in-memory sequence-classification dataset:
/// `xs` is (rows, t, channels) flattened, `labels` is (rows,).
#[derive(Debug, Clone)]
pub struct Dataset {
    pub xs: Vec<f32>,
    pub labels: Vec<i32>,
    pub rows: usize,
    pub t: usize,
    pub channels: usize,
    train_end: usize,
    val_end: usize,
}

impl Dataset {
    /// Wrap generated data with a 70/15/15 split.
    pub fn new(xs: Vec<f32>, labels: Vec<i32>, t: usize, channels: usize) -> Dataset {
        let rows = labels.len();
        assert_eq!(xs.len(), rows * t * channels);
        let train_end = (rows as f64 * 0.70).round() as usize;
        let val_end = train_end + ((rows - train_end) / 2).max(usize::from(rows > train_end));
        Dataset {
            xs,
            labels,
            rows,
            t,
            channels,
            train_end,
            val_end: val_end.min(rows),
        }
    }

    fn range(&self, split: Split) -> std::ops::Range<usize> {
        match split {
            Split::Train => 0..self.train_end,
            Split::Val => self.train_end..self.val_end,
            Split::Test => self.val_end..self.rows,
        }
    }

    pub fn split_len(&self, split: Split) -> usize {
        self.range(split).len()
    }

    /// Copy one row's sequence.
    pub fn row(&self, i: usize) -> &[f32] {
        &self.xs[i * self.t * self.channels..(i + 1) * self.t * self.channels]
    }

    /// Assemble a batch (indices are absolute row ids) → (B, t, c) flat + labels.
    pub fn gather(&self, idx: &[usize]) -> (Vec<f32>, Vec<i32>) {
        let mut xs = Vec::with_capacity(idx.len() * self.t * self.channels);
        let mut labels = Vec::with_capacity(idx.len());
        for &i in idx {
            xs.extend_from_slice(self.row(i));
            labels.push(self.labels[i]);
        }
        (xs, labels)
    }

    /// Random batch of `b` rows from a split.
    pub fn sample_batch(&self, split: Split, b: usize, rng: &mut Rng) -> (Vec<f32>, Vec<i32>, Vec<usize>) {
        let r = self.range(split);
        assert!(!r.is_empty(), "empty split");
        let idx: Vec<usize> = (0..b).map(|_| r.start + rng.below(r.len())).collect();
        let (xs, labels) = self.gather(&idx);
        (xs, labels, idx)
    }

    /// Deterministic batches covering a split (last partial batch dropped).
    pub fn batches(&self, split: Split, b: usize) -> Vec<Vec<usize>> {
        let r = self.range(split);
        r.clone()
            .collect::<Vec<_>>()
            .chunks(b)
            .filter(|c| c.len() == b)
            .map(|c| c.to_vec())
            .collect()
    }

    /// Borrow the `[lo, hi)` timestep window of one row — contiguous because
    /// rows are `(t, channels)` row-major.
    pub fn row_window(&self, i: usize, lo: usize, hi: usize) -> &[f32] {
        debug_assert!(lo <= hi && hi <= self.t);
        &self.xs[(i * self.t + lo) * self.channels..(i * self.t + hi) * self.channels]
    }
}

/// Window-granular sequence source: yields the `[lo, hi)` timestep slice of
/// any row without requiring the full `(rows, t, channels)` tensor to be
/// resident at once. The resident [`Dataset`] implements it by slicing; a
/// [`StreamingDataset`] implements it by regenerating rows on demand. The
/// sharded DEER trainer feeds windows through this trait so peak input
/// memory is O(B · W · c) instead of O(B · T · c).
pub trait WindowSource {
    fn rows(&self) -> usize;
    fn t(&self) -> usize;
    fn channels(&self) -> usize;
    /// Fill `out` (length `(hi - lo) * channels`) with row `row`'s window.
    fn read_window(&mut self, row: usize, lo: usize, hi: usize, out: &mut [f32]);

    /// Assemble a `(idx.len(), hi - lo, channels)` batch window.
    fn gather_window(&mut self, idx: &[usize], lo: usize, hi: usize) -> Vec<f32> {
        let per = (hi - lo) * self.channels();
        let mut out = vec![0.0f32; idx.len() * per];
        for (s, &row) in idx.iter().enumerate() {
            self.read_window(row, lo, hi, &mut out[s * per..(s + 1) * per]);
        }
        out
    }
}

impl WindowSource for Dataset {
    fn rows(&self) -> usize {
        self.rows
    }
    fn t(&self) -> usize {
        self.t
    }
    fn channels(&self) -> usize {
        self.channels
    }
    fn read_window(&mut self, row: usize, lo: usize, hi: usize, out: &mut [f32]) {
        out.copy_from_slice(self.row_window(row, lo, hi));
    }
}

/// Streaming dataset: holds only an O(rows) description (a boxed per-row
/// generator) plus one O(t · channels) scratch row, regenerating rows on
/// demand. Successive window reads of the same row reuse the cached row, so
/// iterating a row window-by-window costs one generation, and the resident
/// footprint never includes the `(rows, t, channels)` tensor.
pub struct StreamingDataset {
    rows: usize,
    t: usize,
    channels: usize,
    row_fn: Box<dyn FnMut(usize, &mut [f32]) + Send>,
    cached: Option<usize>,
    scratch: Vec<f32>,
}

impl StreamingDataset {
    /// `row_fn(row, out)` must deterministically write row `row`'s full
    /// `(t, channels)` sequence into `out`.
    pub fn new(
        rows: usize,
        t: usize,
        channels: usize,
        row_fn: Box<dyn FnMut(usize, &mut [f32]) + Send>,
    ) -> StreamingDataset {
        StreamingDataset {
            rows,
            t,
            channels,
            row_fn,
            cached: None,
            scratch: vec![0.0f32; t * channels],
        }
    }

    /// Bytes held resident (the single scratch row) — what a memory plan
    /// should charge for streaming input, vs `rows * t * channels * 4`
    /// for a resident [`Dataset`].
    pub fn resident_bytes(&self) -> u64 {
        (self.scratch.len() * std::mem::size_of::<f32>()) as u64
    }

    /// Materialize every row into a resident [`Dataset`] (test/debug aid).
    pub fn materialize(&mut self, labels: Vec<i32>) -> Dataset {
        let mut xs = vec![0.0f32; self.rows * self.t * self.channels];
        let per = self.t * self.channels;
        for r in 0..self.rows {
            self.read_window(r, 0, self.t, &mut xs[r * per..(r + 1) * per]);
        }
        Dataset::new(xs, labels, self.t, self.channels)
    }
}

impl WindowSource for StreamingDataset {
    fn rows(&self) -> usize {
        self.rows
    }
    fn t(&self) -> usize {
        self.t
    }
    fn channels(&self) -> usize {
        self.channels
    }
    fn read_window(&mut self, row: usize, lo: usize, hi: usize, out: &mut [f32]) {
        debug_assert!(lo <= hi && hi <= self.t && row < self.rows);
        if self.cached != Some(row) {
            (self.row_fn)(row, &mut self.scratch);
            self.cached = Some(row);
        }
        out.copy_from_slice(&self.scratch[lo * self.channels..hi * self.channels]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Dataset {
        let rows = 20;
        let t = 4;
        let c = 2;
        let xs: Vec<f32> = (0..rows * t * c).map(|i| i as f32).collect();
        let labels: Vec<i32> = (0..rows as i32).collect();
        Dataset::new(xs, labels, t, c)
    }

    #[test]
    fn split_sizes_70_15_15() {
        let d = tiny();
        assert_eq!(d.split_len(Split::Train), 14);
        assert_eq!(d.split_len(Split::Val), 3);
        assert_eq!(d.split_len(Split::Test), 3);
        assert_eq!(
            d.split_len(Split::Train) + d.split_len(Split::Val) + d.split_len(Split::Test),
            d.rows
        );
    }

    #[test]
    fn gather_layout() {
        let d = tiny();
        let (xs, labels) = d.gather(&[1, 3]);
        assert_eq!(labels, vec![1, 3]);
        assert_eq!(xs[..8], d.xs[8..16]);
        assert_eq!(xs[8..], d.xs[24..32]);
    }

    #[test]
    fn sample_batch_stays_in_split() {
        let d = tiny();
        let mut rng = Rng::new(0);
        for _ in 0..20 {
            let (_, _, idx) = d.sample_batch(Split::Val, 2, &mut rng);
            assert!(idx.iter().all(|&i| (14..17).contains(&i)));
        }
    }

    #[test]
    fn batches_cover_split() {
        let d = tiny();
        let bs = d.batches(Split::Train, 4);
        assert_eq!(bs.len(), 3); // 14 rows → 3 full batches of 4
        assert!(bs.iter().flatten().all(|&i| i < 14));
    }

    /// Resident window reads are exact slices of the flat tensor, including
    /// a ragged final window from a non-dividing window size.
    #[test]
    fn dataset_window_reads_slice_resident_tensor() {
        let mut d = tiny(); // t = 4, c = 2
        let (_, spans) = crate::deer::sharded::shard_windows(d.t, 3); // W=2 → (0,2)(2,4)
        assert_eq!(spans, vec![(0, 2), (2, 4)]);
        for &(lo, hi) in &spans {
            let w = d.gather_window(&[1, 3], lo, hi);
            let (full, _) = d.gather(&[1, 3]);
            let per = d.t * d.channels;
            assert_eq!(w[..(hi - lo) * d.channels], full[lo * d.channels..hi * d.channels]);
            assert_eq!(
                w[(hi - lo) * d.channels..],
                full[per + lo * d.channels..per + hi * d.channels]
            );
        }
    }

    /// Satellite: streaming worms reads — window-granular, ragged final
    /// window, non-dividing W — are bitwise-identical to the resident load.
    #[test]
    fn streaming_worms_windows_match_resident_bitwise() {
        let (rows, t, seed) = (6usize, 25usize, 42u64);
        let (xs, labels) = crate::data::worms::generate(rows, t, seed);
        let mut resident = Dataset::new(xs, labels.clone(), t, crate::data::worms::CHANNELS);
        let (mut stream, slabels) = crate::data::worms::streaming(rows, t, seed);
        assert_eq!(labels, slabels);
        assert_eq!(stream.rows(), rows);
        assert!(stream.resident_bytes() < (rows * t * crate::data::worms::CHANNELS * 4) as u64);
        // W = ceil(25/4) = 7 → windows (0,7)(7,14)(14,21)(21,25): ragged tail of 4
        let (w, spans) = crate::deer::sharded::shard_windows(t, 4);
        assert_eq!(w, 7);
        assert_eq!(spans.last(), Some(&(21, 25)));
        let idx: Vec<usize> = (0..rows).collect();
        for &(lo, hi) in &spans {
            assert_eq!(
                stream.gather_window(&idx, lo, hi),
                resident.gather_window(&idx, lo, hi),
                "window [{lo}, {hi})"
            );
        }
        // out-of-order single-row reads (cache churn) stay bitwise too
        let mut buf = vec![0.0f32; 3 * crate::data::worms::CHANNELS];
        for &row in &[5usize, 0, 3, 0] {
            stream.read_window(row, 22, 25, &mut buf);
            assert_eq!(buf, resident.row_window(row, 22, 25));
        }
    }

    /// Satellite: same bitwise guarantee for the two-body regression data.
    #[test]
    fn streaming_twobody_windows_match_resident_bitwise() {
        let (rows, t, seed) = (4usize, 33usize, 9u64);
        let xs = crate::data::twobody::generate(rows, 10.0, t, seed);
        let mut resident = Dataset::new(xs, vec![0; rows], t, crate::data::twobody::STATE);
        let mut stream = crate::data::twobody::streaming(rows, 10.0, t, seed);
        // W = ceil(33/5) = 7 → last window (28,33) of length 5 ≠ 7
        let (_, spans) = crate::deer::sharded::shard_windows(t, 5);
        let idx: Vec<usize> = (0..rows).collect();
        let mut stitched = vec![Vec::new(); rows];
        for &(lo, hi) in &spans {
            let w = stream.gather_window(&idx, lo, hi);
            assert_eq!(w, resident.gather_window(&idx, lo, hi), "window [{lo}, {hi})");
            let per = (hi - lo) * crate::data::twobody::STATE;
            for (s, acc) in stitched.iter_mut().enumerate() {
                acc.extend_from_slice(&w[s * per..(s + 1) * per]);
            }
        }
        // windows concatenated in order reconstruct each full row exactly
        for (r, acc) in stitched.iter().enumerate() {
            assert_eq!(acc[..], *resident.row(r), "row {r}");
        }
    }

    /// `materialize` round-trips a streaming source into a resident Dataset.
    #[test]
    fn streaming_materialize_round_trips() {
        let (rows, t, seed) = (5usize, 12usize, 3u64);
        let (xs, labels) = crate::data::worms::generate(rows, t, seed);
        let (mut stream, slabels) = crate::data::worms::streaming(rows, t, seed);
        let d = stream.materialize(slabels);
        assert_eq!(d.xs, xs);
        assert_eq!(d.labels, labels);
    }
}
