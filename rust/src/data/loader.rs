//! Dataset container, splits and batch iteration.

use crate::util::rng::Rng;

/// Split kind (the paper uses 70/15/15 for EigenWorms, App. B.3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Split {
    Train,
    Val,
    Test,
}

/// An in-memory sequence-classification dataset:
/// `xs` is (rows, t, channels) flattened, `labels` is (rows,).
#[derive(Debug, Clone)]
pub struct Dataset {
    pub xs: Vec<f32>,
    pub labels: Vec<i32>,
    pub rows: usize,
    pub t: usize,
    pub channels: usize,
    train_end: usize,
    val_end: usize,
}

impl Dataset {
    /// Wrap generated data with a 70/15/15 split.
    pub fn new(xs: Vec<f32>, labels: Vec<i32>, t: usize, channels: usize) -> Dataset {
        let rows = labels.len();
        assert_eq!(xs.len(), rows * t * channels);
        let train_end = (rows as f64 * 0.70).round() as usize;
        let val_end = train_end + ((rows - train_end) / 2).max(usize::from(rows > train_end));
        Dataset {
            xs,
            labels,
            rows,
            t,
            channels,
            train_end,
            val_end: val_end.min(rows),
        }
    }

    fn range(&self, split: Split) -> std::ops::Range<usize> {
        match split {
            Split::Train => 0..self.train_end,
            Split::Val => self.train_end..self.val_end,
            Split::Test => self.val_end..self.rows,
        }
    }

    pub fn split_len(&self, split: Split) -> usize {
        self.range(split).len()
    }

    /// Copy one row's sequence.
    pub fn row(&self, i: usize) -> &[f32] {
        &self.xs[i * self.t * self.channels..(i + 1) * self.t * self.channels]
    }

    /// Assemble a batch (indices are absolute row ids) → (B, t, c) flat + labels.
    pub fn gather(&self, idx: &[usize]) -> (Vec<f32>, Vec<i32>) {
        let mut xs = Vec::with_capacity(idx.len() * self.t * self.channels);
        let mut labels = Vec::with_capacity(idx.len());
        for &i in idx {
            xs.extend_from_slice(self.row(i));
            labels.push(self.labels[i]);
        }
        (xs, labels)
    }

    /// Random batch of `b` rows from a split.
    pub fn sample_batch(&self, split: Split, b: usize, rng: &mut Rng) -> (Vec<f32>, Vec<i32>, Vec<usize>) {
        let r = self.range(split);
        assert!(!r.is_empty(), "empty split");
        let idx: Vec<usize> = (0..b).map(|_| r.start + rng.below(r.len())).collect();
        let (xs, labels) = self.gather(&idx);
        (xs, labels, idx)
    }

    /// Deterministic batches covering a split (last partial batch dropped).
    pub fn batches(&self, split: Split, b: usize) -> Vec<Vec<usize>> {
        let r = self.range(split);
        r.clone()
            .collect::<Vec<_>>()
            .chunks(b)
            .filter(|c| c.len() == b)
            .map(|c| c.to_vec())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Dataset {
        let rows = 20;
        let t = 4;
        let c = 2;
        let xs: Vec<f32> = (0..rows * t * c).map(|i| i as f32).collect();
        let labels: Vec<i32> = (0..rows as i32).collect();
        Dataset::new(xs, labels, t, c)
    }

    #[test]
    fn split_sizes_70_15_15() {
        let d = tiny();
        assert_eq!(d.split_len(Split::Train), 14);
        assert_eq!(d.split_len(Split::Val), 3);
        assert_eq!(d.split_len(Split::Test), 3);
        assert_eq!(
            d.split_len(Split::Train) + d.split_len(Split::Val) + d.split_len(Split::Test),
            d.rows
        );
    }

    #[test]
    fn gather_layout() {
        let d = tiny();
        let (xs, labels) = d.gather(&[1, 3]);
        assert_eq!(labels, vec![1, 3]);
        assert_eq!(xs[..8], d.xs[8..16]);
        assert_eq!(xs[8..], d.xs[24..32]);
    }

    #[test]
    fn sample_batch_stays_in_split() {
        let d = tiny();
        let mut rng = Rng::new(0);
        for _ in 0..20 {
            let (_, _, idx) = d.sample_batch(Split::Val, 2, &mut rng);
            assert!(idx.iter().all(|&i| (14..17).contains(&i)));
        }
    }

    #[test]
    fn batches_cover_split() {
        let d = tiny();
        let bs = d.batches(Split::Train, 4);
        assert_eq!(bs.len(), 3); // 14 rows → 3 full batches of 4
        assert!(bs.iter().flatten().all(|&i| i < 14));
    }
}
