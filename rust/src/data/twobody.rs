//! Two-body gravitational system (paper §4.2 / App. B.2).
//!
//! States `s = (x1, y1, vx1, vy1, x2, y2, vx2, vy2)`; unit masses, G = 1.
//! Initial conditions are sampled near circular orbits so trajectories stay
//! bounded (App. B.2), rolled out on t ∈ [0, t_end] with an RK4 fine grid.
//! Also implements [`OdeSystem`] with the analytic gravity Jacobian so the
//! Rust DEER-ODE solver can integrate the true dynamics directly.

use crate::deer::ode::OdeSystem;
use crate::util::rng::Rng;

/// The two-body vector field (unit masses, G = 1).
pub struct TwoBody;

pub const STATE: usize = 8;

impl OdeSystem<f64> for TwoBody {
    fn dim(&self) -> usize {
        STATE
    }

    fn f(&self, _t: f64, s: &[f64], out: &mut [f64]) {
        let (x1, y1, vx1, vy1, x2, y2, vx2, vy2) =
            (s[0], s[1], s[2], s[3], s[4], s[5], s[6], s[7]);
        let dx = x2 - x1;
        let dy = y2 - y1;
        let r2 = dx * dx + dy * dy;
        let r3 = r2 * r2.sqrt();
        let ax1 = dx / r3; // m2 = 1
        let ay1 = dy / r3;
        out[0] = vx1;
        out[1] = vy1;
        out[2] = ax1;
        out[3] = ay1;
        out[4] = vx2;
        out[5] = vy2;
        out[6] = -ax1; // m1 = 1
        out[7] = -ay1;
    }

    fn jac(&self, _t: f64, s: &[f64], out: &mut [f64]) {
        // d(acc)/d(pos): for a = d/|d|³ with d = p2 − p1,
        // ∂a/∂d = I/|d|³ − 3 d dᵀ/|d|⁵.
        for v in out.iter_mut() {
            *v = 0.0;
        }
        let n = STATE;
        let dx = s[4] - s[0];
        let dy = s[5] - s[1];
        let r2 = dx * dx + dy * dy;
        let r = r2.sqrt();
        let r3 = r2 * r;
        let r5 = r2 * r3;
        // 2x2 block K = I/r³ − 3 ddᵀ/r⁵
        let kxx = 1.0 / r3 - 3.0 * dx * dx / r5;
        let kxy = -3.0 * dx * dy / r5;
        let kyy = 1.0 / r3 - 3.0 * dy * dy / r5;

        // position derivatives: d(pos)/dt = vel
        out[n + 3] = 1.0; // row1: dy1' /dvy1
        out[3] = 0.0;
        out[2] = 1.0; // row0: dx1'/dvx1
        out[4 * n + 6] = 1.0; // row4: dx2'/dvx2
        out[5 * n + 7] = 1.0; // row5: dy2'/dvy2

        // a1 = K·(p2 − p1) differentiated: ∂a1/∂p2 = K, ∂a1/∂p1 = −K
        // rows 2..3 (a1), rows 6..7 (a2 = −a1)
        let put = |out: &mut [f64], row: usize, col: usize, v: f64| {
            out[row * n + col] = v;
        };
        // ∂a1x
        put(out, 2, 0, -kxx);
        put(out, 2, 1, -kxy);
        put(out, 2, 4, kxx);
        put(out, 2, 5, kxy);
        // ∂a1y
        put(out, 3, 0, -kxy);
        put(out, 3, 1, -kyy);
        put(out, 3, 4, kxy);
        put(out, 3, 5, kyy);
        // a2 = −a1
        put(out, 6, 0, kxx);
        put(out, 6, 1, kxy);
        put(out, 6, 4, -kxx);
        put(out, 6, 5, -kxy);
        put(out, 7, 0, kxy);
        put(out, 7, 1, kyy);
        put(out, 7, 4, -kxy);
        put(out, 7, 5, -kyy);
    }
}

/// Sample a near-circular initial condition (App. B.2: orbits close to a
/// circle so the simulation stays numerically stable).
pub fn sample_ic(rng: &mut Rng) -> [f64; STATE] {
    let sep = rng.uniform_in(0.8, 1.4); // body separation
    let ecc = rng.uniform_in(0.9, 1.1); // tangential velocity factor
    let phase = rng.uniform_in(0.0, 2.0 * std::f64::consts::PI);
    // circular relative speed for total mass 2: v² = GM/r = 2/sep; each body
    // moves at half the relative velocity around the barycentre.
    let v_rel = (2.0 / sep).sqrt() * ecc;
    let (c, s) = (phase.cos(), phase.sin());
    let hx = 0.5 * sep * c;
    let hy = 0.5 * sep * s;
    let hvx = -0.5 * v_rel * s;
    let hvy = 0.5 * v_rel * c;
    [hx, hy, hvx, hvy, -hx, -hy, -hvx, -hvy]
}

/// Roll one trajectory on a uniform grid with fine-substep RK4.
pub fn rollout(ic: &[f64; STATE], t_end: f64, samples: usize, substeps: usize) -> Vec<f64> {
    let sys = TwoBody;
    let mut out = Vec::with_capacity(samples * STATE);
    let mut s = *ic;
    out.extend_from_slice(&s);
    let dt_sample = t_end / (samples - 1) as f64;
    let h = dt_sample / substeps as f64;
    let mut k1 = [0.0; STATE];
    let mut k2 = [0.0; STATE];
    let mut k3 = [0.0; STATE];
    let mut k4 = [0.0; STATE];
    let mut tmp = [0.0; STATE];
    for i in 1..samples {
        for _ in 0..substeps {
            sys.f(0.0, &s, &mut k1);
            for j in 0..STATE {
                tmp[j] = s[j] + 0.5 * h * k1[j];
            }
            sys.f(0.0, &tmp, &mut k2);
            for j in 0..STATE {
                tmp[j] = s[j] + 0.5 * h * k2[j];
            }
            sys.f(0.0, &tmp, &mut k3);
            for j in 0..STATE {
                tmp[j] = s[j] + h * k3[j];
            }
            sys.f(0.0, &tmp, &mut k4);
            for j in 0..STATE {
                s[j] += h / 6.0 * (k1[j] + 2.0 * k2[j] + 2.0 * k3[j] + k4[j]);
            }
        }
        out.extend_from_slice(&s);
        let _ = i;
    }
    out
}

/// Generate a dataset of `rows` trajectories (flattened f32, row-major
/// (rows, samples, 8)) — the paper uses 1000 rows, t ∈ [0, 10], 10k samples.
pub fn generate(rows: usize, t_end: f64, samples: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    let mut out = Vec::with_capacity(rows * samples * STATE);
    for _ in 0..rows {
        let ic = sample_ic(&mut rng);
        let traj = rollout(&ic, t_end, samples, 4);
        out.extend(traj.iter().map(|&v| v as f32));
    }
    out
}

/// Streaming variant of [`generate`]: pre-draws the O(rows) initial
/// conditions with the same RNG order as [`generate`], then re-rolls each
/// trajectory on demand. Window reads are bitwise-identical to slicing the
/// resident tensor, with only one `samples × STATE` scratch row held.
pub fn streaming(rows: usize, t_end: f64, samples: usize, seed: u64) -> crate::data::loader::StreamingDataset {
    let mut rng = Rng::new(seed);
    let ics: Vec<[f64; STATE]> = (0..rows).map(|_| sample_ic(&mut rng)).collect();
    crate::data::loader::StreamingDataset::new(
        rows,
        samples,
        STATE,
        Box::new(move |row, out: &mut [f32]| {
            let traj = rollout(&ics[row], t_end, samples, 4);
            for (o, v) in out.iter_mut().zip(traj.iter()) {
                *o = *v as f32;
            }
        }),
    )
}

/// Total energy (kinetic + gravitational potential), conserved by the flow.
pub fn energy(s: &[f64]) -> f64 {
    let ke = 0.5 * (s[2] * s[2] + s[3] * s[3] + s[6] * s[6] + s[7] * s[7]);
    let dx = s[4] - s[0];
    let dy = s[5] - s[1];
    let r = (dx * dx + dy * dy).sqrt();
    ke - 1.0 / r
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn jacobian_matches_fd() {
        let sys = TwoBody;
        let mut rng = Rng::new(4);
        let ic = sample_ic(&mut rng);
        let mut jac = vec![0.0; STATE * STATE];
        sys.jac(0.0, &ic, &mut jac);
        let eps = 1e-6;
        let mut fp = vec![0.0; STATE];
        let mut fm = vec![0.0; STATE];
        for j in 0..STATE {
            let mut sp = ic;
            let mut sm = ic;
            sp[j] += eps;
            sm[j] -= eps;
            sys.f(0.0, &sp, &mut fp);
            sys.f(0.0, &sm, &mut fm);
            for i in 0..STATE {
                let fd = (fp[i] - fm[i]) / (2.0 * eps);
                assert!(
                    (jac[i * STATE + j] - fd).abs() < 1e-5,
                    "J[{i},{j}]: {} vs {fd}",
                    jac[i * STATE + j]
                );
            }
        }
    }

    #[test]
    fn energy_conserved_along_rollout() {
        let mut rng = Rng::new(7);
        let ic = sample_ic(&mut rng);
        let traj = rollout(&ic, 10.0, 200, 16);
        let e0 = energy(&traj[..STATE]);
        for k in (0..200).step_by(20) {
            let e: f64 = energy(&traj[k * STATE..(k + 1) * STATE]);
            assert!((e - e0).abs() < 1e-4 * e0.abs().max(1.0), "step {k}: {e} vs {e0}");
        }
    }

    #[test]
    fn momentum_zero_by_construction() {
        let mut rng = Rng::new(9);
        let ic = sample_ic(&mut rng);
        assert!((ic[2] + ic[6]).abs() < 1e-12);
        assert!((ic[3] + ic[7]).abs() < 1e-12);
    }

    #[test]
    fn orbits_stay_bounded() {
        let mut rng = Rng::new(11);
        for _ in 0..5 {
            let ic = sample_ic(&mut rng);
            let traj = rollout(&ic, 10.0, 500, 4);
            for k in 0..500 {
                let s = &traj[k * STATE..(k + 1) * STATE];
                let r = ((s[0] - s[4]).powi(2) + (s[1] - s[5]).powi(2)).sqrt();
                assert!(r > 0.05 && r < 10.0, "separation {r} at step {k}");
            }
        }
    }

    #[test]
    fn generate_shape() {
        let d = generate(3, 2.0, 50, 1);
        assert_eq!(d.len(), 3 * 50 * STATE);
        assert!(d.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn deer_ode_solves_two_body() {
        // The Rust DEER-ODE solver integrates the real dynamics and matches
        // the RK4 rollout (§4.2's substrate, end-to-end in Rust).
        use crate::deer::newton::DeerConfig;
        use crate::deer::ode::{deer_ode, Interp};
        let mut rng = Rng::new(3);
        let ic = sample_ic(&mut rng);
        let samples = 400;
        let t_end = 2.0;
        let fine = rollout(&ic, t_end, samples, 16);
        let ts: Vec<f64> = (0..samples)
            .map(|i| t_end * i as f64 / (samples - 1) as f64)
            .collect();
        let res = deer_ode(
            &TwoBody,
            &ts,
            &ic,
            Some(&fine), // warm start from the reference (training-style)
            Interp::Midpoint,
            &DeerConfig { tol: 1e-9, ..Default::default() },
        );
        assert!(res.converged, "trace {:?}", res.err_trace);
        let mut max_err = 0.0f64;
        for k in 0..samples {
            for j in 0..STATE {
                max_err = max_err.max((res.ys[k * STATE + j] - fine[k * STATE + j]).abs());
            }
        }
        assert!(max_err < 2e-3, "max err {max_err}");
    }
}
