//! Synthetic sequential-CIFAR (substitute for torchvision CIFAR-10, §4.4).
//!
//! Real CIFAR-10 is unavailable offline; this generator produces 32×32×3
//! "images" (flattened to 1024-step, 3-channel sequences exactly as App. B.4
//! does) whose class is carried by procedural texture statistics — grating
//! orientation/frequency plus colour gradients — so that, serialized to a
//! raster-scan sequence, class evidence is spread across the whole 1024-step
//! horizon. The multi-head strided GRU path is exercised identically.

use crate::util::rng::Rng;

pub const SIDE: usize = 32;
pub const CHANNELS: usize = 3;
pub const SEQ_LEN: usize = SIDE * SIDE;
pub const CLASSES: usize = 10;

/// Per-class texture parameters.
fn class_params(class: usize) -> (f64, f64, f64) {
    // (grating frequency, orientation, colour-gradient angle)
    let f = 2.0 + (class % 5) as f64 * 1.5;
    let theta = (class as f64) * std::f64::consts::PI / CLASSES as f64;
    let grad = (class as f64) * std::f64::consts::TAU / CLASSES as f64;
    (f, theta, grad)
}

/// One image as a (SEQ_LEN, CHANNELS) sequence, normalized ~N(0,1)-ish.
pub fn sample(class: usize, rng: &mut Rng) -> Vec<f32> {
    let (f, theta, grad) = class_params(class);
    let f = f * rng.uniform_in(0.9, 1.1);
    let phase = rng.uniform_in(0.0, std::f64::consts::TAU);
    let (ct, st) = (theta.cos(), theta.sin());
    let (cg, sg) = (grad.cos(), grad.sin());
    let mut out = Vec::with_capacity(SEQ_LEN * CHANNELS);
    for yy in 0..SIDE {
        for xx in 0..SIDE {
            let u = xx as f64 / SIDE as f64 - 0.5;
            let v = yy as f64 / SIDE as f64 - 0.5;
            let g = (std::f64::consts::TAU * f * (u * ct + v * st) + phase).sin();
            let ramp = u * cg + v * sg;
            for c in 0..CHANNELS {
                let chroma = match c {
                    0 => 1.0,
                    1 => 0.6,
                    _ => -0.8,
                };
                let val = 0.8 * g + 1.2 * ramp * chroma + 0.25 * rng.normal();
                out.push(val as f32);
            }
        }
    }
    out
}

/// Dataset: (rows, SEQ_LEN, CHANNELS) flattened + labels, class-balanced.
pub fn generate(rows: usize, seed: u64) -> (Vec<f32>, Vec<i32>) {
    let mut rng = Rng::new(seed);
    let order = rng.permutation(rows);
    let mut xs = vec![0.0f32; rows * SEQ_LEN * CHANNELS];
    let mut labels = vec![0i32; rows];
    for (slot, &row) in order.iter().enumerate() {
        let class = slot % CLASSES;
        let mut srng = rng.split();
        let img = sample(class, &mut srng);
        xs[row * SEQ_LEN * CHANNELS..(row + 1) * SEQ_LEN * CHANNELS].copy_from_slice(&img);
        labels[row] = class as i32;
    }
    (xs, labels)
}

/// Downscale a sample to a (t, CHANNELS) sequence by strided subsampling —
/// used when artifacts are compiled for shorter sequence lengths.
pub fn subsample(img: &[f32], t: usize) -> Vec<f32> {
    assert!(t <= SEQ_LEN);
    let stride = SEQ_LEN / t;
    let mut out = Vec::with_capacity(t * CHANNELS);
    for i in 0..t {
        let p = (i * stride).min(SEQ_LEN - 1);
        out.extend_from_slice(&img[p * CHANNELS..(p + 1) * CHANNELS]);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_and_balance() {
        let (xs, labels) = generate(20, 5);
        assert_eq!(xs.len(), 20 * SEQ_LEN * CHANNELS);
        let mut counts = [0usize; CLASSES];
        for &l in &labels {
            counts[l as usize] += 1;
        }
        assert!(counts.iter().all(|&c| c == 2));
    }

    #[test]
    fn deterministic() {
        assert_eq!(generate(3, 9).0, generate(3, 9).0);
    }

    #[test]
    fn classes_have_distinct_textures() {
        // Lag-1 autocorrelation of the horizontally *differenced* channel 0
        // (differencing removes the colour ramp) separates grating
        // frequencies. Differenced white noise has ac −0.5; a low-frequency
        // grating adds little diff energy (ac stays near the noise limit)
        // while a high-frequency grating contributes strong diffs with
        // lag-1 correlation cos(Δφ)≈0, pulling the statistic toward 0.
        let diff_ac = |class: usize| -> f64 {
            let mut rng = Rng::new(13);
            let img = sample(class, &mut rng);
            let ch0: Vec<f32> = img.chunks(CHANNELS).map(|p| p[0]).collect();
            let (mut num, mut den) = (0.0f64, 0.0f64);
            for row in 0..SIDE {
                let r = &ch0[row * SIDE..(row + 1) * SIDE];
                let d: Vec<f64> = r.windows(2).map(|w| (w[1] - w[0]) as f64).collect();
                for k in 0..d.len() - 1 {
                    num += d[k] * d[k + 1];
                    den += d[k] * d[k];
                }
            }
            num / den
        };
        assert!(
            diff_ac(4) > diff_ac(0) + 0.05,
            "{} vs {}",
            diff_ac(4),
            diff_ac(0)
        );
    }

    #[test]
    fn subsample_lengths() {
        let mut rng = Rng::new(1);
        let img = sample(2, &mut rng);
        let s = subsample(&img, 128);
        assert_eq!(s.len(), 128 * CHANNELS);
    }
}
