//! Dataset substrates.
//!
//! The paper's experiments use datasets this environment doesn't ship
//! (EigenWorms from UEA, CIFAR-10 from torchvision) plus a generated
//! two-body physics dataset. Per the substitution rules, [`worms`] and
//! [`cifar_seq`] are synthetic generators that preserve the properties the
//! experiments exercise (sequence length, channel count, class structure,
//! learnability by a recurrent model), and [`twobody`] implements the
//! paper's own generated dataset (App. B.2). [`loader`] provides splits and
//! batch iteration.

pub mod cifar_seq;
pub mod loader;
pub mod twobody;
pub mod worms;

pub use loader::{Dataset, Split};
