//! Synthetic EigenWorms (substitute for Brown et al., 2013 / UEA).
//!
//! The real dataset — 259 C. elegans locomotion recordings, each 17,984
//! samples of 6 "eigenworm" shape coefficients, 5 classes (wild-type + 4
//! mutants) — is not available offline. This generator preserves what the
//! §4.3 experiment exercises:
//!
//! * the same tensor geometry (259 × 17,984 × 6, 70/15/15 split),
//! * class structure carried by *temporal dynamics*, not static statistics:
//!   each class differs in undulation frequency band, inter-channel phase
//!   coupling, and the rate of a slow amplitude-modulation envelope, so a
//!   classifier must integrate over long horizons (the property that makes
//!   EigenWorms a long-sequence benchmark),
//! * matched first/second moments across classes (no trivial shortcuts).

use crate::util::rng::Rng;

pub const CHANNELS: usize = 6;
pub const CLASSES: usize = 5;
pub const FULL_LEN: usize = 17_984;
pub const FULL_ROWS: usize = 259;

/// Per-class dynamics parameters (frequency in cycles/sequence-length units).
fn class_params(class: usize) -> (f64, f64, f64) {
    // (base undulation freq, phase coupling, AM envelope freq)
    match class {
        0 => (7.0, 0.50, 0.8),
        1 => (10.0, 0.85, 1.3),
        2 => (13.0, 0.20, 0.5),
        3 => (16.0, 0.65, 2.1),
        _ => (19.0, 0.35, 1.7),
    }
}

/// Generate one sample: `len × CHANNELS` f32, deterministic in `rng`.
pub fn sample(class: usize, len: usize, rng: &mut Rng) -> Vec<f32> {
    let (freq, coupling, am_freq) = class_params(class);
    let freq = freq * rng.uniform_in(0.9, 1.1);
    let phase0 = rng.uniform_in(0.0, std::f64::consts::TAU);
    let am_phase = rng.uniform_in(0.0, std::f64::consts::TAU);
    // smooth per-channel amplitude profile (eigen-shape weights)
    let amps: Vec<f64> = (0..CHANNELS).map(|c| 1.0 / (1.0 + 0.35 * c as f64)).collect();
    let mut out = Vec::with_capacity(len * CHANNELS);
    // slow AR(1) drift shared across channels (worm posture baseline)
    let mut drift = 0.0f64;
    let rho = 0.999;
    for i in 0..len {
        let t = i as f64 / len as f64;
        drift = rho * drift + 0.02 * rng.normal();
        let env = 1.0 + 0.4 * (std::f64::consts::TAU * am_freq * t + am_phase).sin();
        for (c, amp) in amps.iter().enumerate() {
            let phase = phase0 + coupling * c as f64;
            let v = amp
                * env
                * (std::f64::consts::TAU * freq * t + phase).sin()
                + 0.3 * drift
                + 0.15 * rng.normal();
            out.push(v as f32);
        }
    }
    out
}

/// Streaming variant of [`generate`]: replays the same RNG skeleton
/// (permutation + one `split` per slot) to build an O(rows) table of
/// per-row `(class, rng)` pairs, then regenerates individual rows on
/// demand. Window reads are bitwise-identical to slicing the resident
/// tensor from [`generate`] with the same `seed`, while holding only one
/// `len × CHANNELS` scratch row.
pub fn streaming(rows: usize, len: usize, seed: u64) -> (crate::data::loader::StreamingDataset, Vec<i32>) {
    let mut rng = Rng::new(seed);
    let order = rng.permutation(rows);
    let mut table: Vec<(usize, Rng)> = vec![(0, Rng::new(0)); rows];
    let mut labels = vec![0i32; rows];
    for (slot, &row) in order.iter().enumerate() {
        let class = slot % CLASSES;
        table[row] = (class, rng.split());
        labels[row] = class as i32;
    }
    let ds = crate::data::loader::StreamingDataset::new(
        rows,
        len,
        CHANNELS,
        Box::new(move |row, out: &mut [f32]| {
            let class = table[row].0;
            let mut srng = table[row].1.clone();
            out.copy_from_slice(&sample(class, len, &mut srng));
        }),
    );
    (ds, labels)
}

/// Generate the full dataset: (rows, len, CHANNELS) flattened + labels,
/// classes assigned round-robin then shuffled (class-balanced like UEA).
pub fn generate(rows: usize, len: usize, seed: u64) -> (Vec<f32>, Vec<i32>) {
    let mut rng = Rng::new(seed);
    let order = rng.permutation(rows);
    let mut xs = vec![0.0f32; rows * len * CHANNELS];
    let mut labels = vec![0i32; rows];
    for (slot, &row) in order.iter().enumerate() {
        let class = slot % CLASSES;
        let mut srng = rng.split();
        let s = sample(class, len, &mut srng);
        xs[row * len * CHANNELS..(row + 1) * len * CHANNELS].copy_from_slice(&s);
        labels[row] = class as i32;
    }
    (xs, labels)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_balance() {
        let (xs, labels) = generate(20, 64, 1);
        assert_eq!(xs.len(), 20 * 64 * CHANNELS);
        assert_eq!(labels.len(), 20);
        let mut counts = [0usize; CLASSES];
        for &l in &labels {
            counts[l as usize] += 1;
        }
        assert!(counts.iter().all(|&c| c == 4));
    }

    #[test]
    fn deterministic() {
        let (a, la) = generate(5, 32, 42);
        let (b, lb) = generate(5, 32, 42);
        assert_eq!(a, b);
        assert_eq!(la, lb);
    }

    #[test]
    fn values_bounded_and_varied() {
        let (xs, _) = generate(4, 256, 7);
        assert!(xs.iter().all(|v| v.is_finite() && v.abs() < 10.0));
        let mean: f32 = xs.iter().sum::<f32>() / xs.len() as f32;
        let var: f32 = xs.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / xs.len() as f32;
        assert!(var > 0.05, "variance {var}");
    }

    #[test]
    fn classes_not_separable_by_mean() {
        // The class signal is temporal; per-sample means must overlap.
        let len = 512;
        let mut rng = Rng::new(3);
        let mut means = vec![];
        for class in 0..CLASSES {
            let s = sample(class, len, &mut rng);
            means.push(s.iter().sum::<f32>() / s.len() as f32);
        }
        let spread = means.iter().cloned().fold(f32::MIN, f32::max)
            - means.iter().cloned().fold(f32::MAX, f32::min);
        assert!(spread < 0.5, "class means too separated: {means:?}");
    }

    #[test]
    fn classes_differ_in_spectrum() {
        // Matched filter: the spectral power of channel 0 at a class's own
        // base frequency must exceed its power at the other class's band.
        let len = 2048;
        let power_at = |sig: &[f32], freq: f64| -> f64 {
            let (mut ps, mut pc) = (0.0f64, 0.0f64);
            for (i, &v) in sig.iter().enumerate() {
                let ph = std::f64::consts::TAU * freq * i as f64 / len as f64;
                ps += v as f64 * ph.sin();
                pc += v as f64 * ph.cos();
            }
            ps * ps + pc * pc
        };
        let ch0 = |class: usize, seed: u64| -> Vec<f32> {
            let mut rng = Rng::new(seed);
            sample(class, len, &mut rng).chunks(CHANNELS).map(|c| c[0]).collect()
        };
        // freq bands (±10% jitter in the generator → integrate over a window)
        let band = |sig: &[f32], f0: f64| -> f64 {
            (-2..=2).map(|k| power_at(sig, f0 + k as f64 * 0.5)).sum()
        };
        let (f_lo, _, _) = class_params(0);
        let (f_hi, _, _) = class_params(4);
        let mut own = 0.0;
        let mut cross = 0.0;
        for seed in 0..4 {
            let s0 = ch0(0, seed);
            let s4 = ch0(4, 100 + seed);
            own += band(&s0, f_lo) + band(&s4, f_hi);
            cross += band(&s0, f_hi) + band(&s4, f_lo);
        }
        assert!(own > 4.0 * cross, "own-band power {own} vs cross-band {cross}");
    }
}
