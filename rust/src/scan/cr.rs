//! Cyclic-reduction (Hillis–Steele) scans: O(⌈log₂ L⌉) depth, O(L·log L)
//! work — the schedule that wins when threads ≈ L and the chunked two-pass
//! scan would starve workers (DeepPCR's observation; see
//! [`super::choose_scan_schedule`]).
//!
//! # The sweep
//!
//! All eight entry points run the same doubling recursion over the affine
//! monoid of eq. (10). Level `d` (stride `2^d`) replaces every element with
//! its composition against the element `2^d` positions away:
//!
//! ```text
//! forward (prefix):  x_i ← x_i • x_{i−2^d}     (i ≥ 2^d; else copy)
//! reverse (suffix):  x_i ← x_i • x_{i+2^d}     (i + 2^d < L; else copy)
//! ```
//!
//! where `•` is the structure's combine with `x_i` as the *later* operand.
//! After ⌈log₂ L⌉ levels, forward `x_i` holds the prefix product
//! `E_i • … • E_0` — one apply against `y0` yields the solution — and
//! reverse `x_i` holds the suffix product of the dual elements
//! `F_i = (A_{i+1}ᵀ, g_i)` (beyond-end `A` is 0), whose vector part *is*
//! `λ_i` directly.
//!
//! Each level is a barrier: elements are read from one half of a ping-pong
//! buffer pair (carved from the caller's [`ScanWorkspace`]) and written to
//! the other, with the index range split contiguously over the workers.
//! The final apply pass is parallelized the same way, so the modeled
//! critical path is `⌈log₂L⌉·(⌈L/threads⌉·combine + sync) +
//! ⌈L/threads⌉·apply + sync` — exactly the expression
//! [`super::choose_scan_schedule`] prices.
//!
//! # Numerical contract
//!
//! Cyclic reduction associates the combines differently from the
//! sequential replay, so — unlike the chunked schedule's phase-3 replay,
//! which is bitwise-identical per chunk — CR results agree with the
//! sequential kernels only to rounding (the monoid is exactly associative
//! in real arithmetic; tests pin agreement at tight tolerances and pin the
//! associativity property itself). The damped (Kalman) variants at λ = 0
//! route to the *plain* CR kernels bit-for-bit, mirroring
//! [`super::kalman`]'s dispatch contract.
//!
//! Batched `[B, T, n]` callers reach these kernels through the batch
//! scheduling layer (`par_*_batch_ws`), which handles the active mask and
//! only splits *inside* a sequence when `B < threads` — so CR inherits
//! convergence masking without needing a masked variant of its own.

use super::kalman::{apply_a, damp_gain};
use super::{combine, combine_block, combine_diag, ScanWorkspace};
use crate::cells::JacobianStructure;
use crate::util::scalar::Scalar;

/// `out = later ∘ earlier` through the structure's combine.
#[allow(clippy::too_many_arguments)]
#[inline]
fn compose_st<S: Scalar>(
    st: JacobianStructure,
    a_later: &[S],
    b_later: &[S],
    a_earlier: &[S],
    b_earlier: &[S],
    a_out: &mut [S],
    b_out: &mut [S],
    n: usize,
) {
    match st {
        JacobianStructure::Dense => {
            combine(a_later, b_later, a_earlier, b_earlier, a_out, b_out, n)
        }
        JacobianStructure::Diagonal => {
            combine_diag(a_later, b_later, a_earlier, b_earlier, a_out, b_out, n)
        }
        JacobianStructure::Block { k } => {
            combine_block(a_later, b_later, a_earlier, b_earlier, a_out, b_out, n, k)
        }
    }
}

/// Contiguous `(lo, hi)` worker ranges covering `[0, len)`.
fn worker_ranges(len: usize, threads: usize) -> Vec<(usize, usize)> {
    let workers = threads.clamp(1, len.max(1));
    let chunk = len.div_ceil(workers);
    (0..workers)
        .map(|c| ((c * chunk).min(len), ((c + 1) * chunk).min(len)))
        .filter(|&(lo, hi)| lo < hi)
        .collect()
}

/// Run the doubling levels over elements already staged in the first half
/// of `buf_a`/`buf_b` (each buffer holds two `len`-element halves).
/// Returns `true` when the result landed in the second half.
fn cr_levels<S: Scalar>(
    st: JacobianStructure,
    n: usize,
    len: usize,
    threads: usize,
    reverse: bool,
    buf_a: &mut [S],
    buf_b: &mut [S],
) -> bool {
    let jl = st.jac_len(n);
    let (a0, a1) = buf_a.split_at_mut(len * jl);
    let (b0, b1) = buf_b.split_at_mut(len * n);
    let ranges = worker_ranges(len, threads);
    let mut flip = false;
    let mut stride = 1usize;
    while stride < len {
        {
            let (src_a, dst_a, src_b, dst_b): (&[S], &mut [S], &[S], &mut [S]) = if !flip {
                (&*a0, &mut *a1, &*b0, &mut *b1)
            } else {
                (&*a1, &mut *a0, &*b1, &mut *b0)
            };
            std::thread::scope(|scope| {
                let mut rest_a = dst_a;
                let mut rest_b = dst_b;
                let mut consumed = 0usize;
                for &(lo, hi) in &ranges {
                    debug_assert_eq!(lo, consumed);
                    let (ca, ta) = rest_a.split_at_mut((hi - lo) * jl);
                    let (cb, tb) = rest_b.split_at_mut((hi - lo) * n);
                    rest_a = ta;
                    rest_b = tb;
                    consumed = hi;
                    scope.spawn(move || {
                        for i in lo..hi {
                            let oi = i - lo;
                            let partner = if reverse {
                                (i + stride < len).then(|| i + stride)
                            } else {
                                (i >= stride).then(|| i - stride)
                            };
                            let ao = &mut ca[oi * jl..(oi + 1) * jl];
                            let bo = &mut cb[oi * n..(oi + 1) * n];
                            match partner {
                                Some(j) => compose_st(
                                    st,
                                    &src_a[i * jl..(i + 1) * jl],
                                    &src_b[i * n..(i + 1) * n],
                                    &src_a[j * jl..(j + 1) * jl],
                                    &src_b[j * n..(j + 1) * n],
                                    ao,
                                    bo,
                                    n,
                                ),
                                None => {
                                    ao.copy_from_slice(&src_a[i * jl..(i + 1) * jl]);
                                    bo.copy_from_slice(&src_b[i * n..(i + 1) * n]);
                                }
                            }
                        }
                    });
                }
            });
        }
        flip = !flip;
        stride *= 2;
    }
    flip
}

/// Shared forward driver: elements `(el_a, el_b)` are staged by `init`
/// (one call per index, writing the packed level-0 element), swept to
/// prefix products, then applied to `y0` in parallel.
fn cr_apply_driver<S: Scalar>(
    st: JacobianStructure,
    y0: &[S],
    out: &mut [S],
    n: usize,
    len: usize,
    threads: usize,
    ws: &mut ScanWorkspace<S>,
    init: impl Fn(usize, &mut [S], &mut [S]) + Sync,
) {
    if len == 0 {
        return;
    }
    let jl = st.jac_len(n);
    ws.ensure(2 * len * jl, 2 * len * n, 0);
    let buf_a = &mut ws.comp_a[..2 * len * jl];
    let buf_b = &mut ws.comp_b[..2 * len * n];
    let ranges = worker_ranges(len, threads);
    {
        let (stage_a, _) = buf_a.split_at_mut(len * jl);
        let (stage_b, _) = buf_b.split_at_mut(len * n);
        std::thread::scope(|scope| {
            let mut rest_a = stage_a;
            let mut rest_b = stage_b;
            for &(lo, hi) in &ranges {
                let (ca, ta) = rest_a.split_at_mut((hi - lo) * jl);
                let (cb, tb) = rest_b.split_at_mut((hi - lo) * n);
                rest_a = ta;
                rest_b = tb;
                let init = &init;
                scope.spawn(move || {
                    for i in lo..hi {
                        let oi = i - lo;
                        init(i, &mut ca[oi * jl..(oi + 1) * jl], &mut cb[oi * n..(oi + 1) * n]);
                    }
                });
            }
        });
    }
    let flip = cr_levels(st, n, len, threads, false, buf_a, buf_b);
    let half_a = if flip { &buf_a[len * jl..] } else { &buf_a[..len * jl] };
    let half_b = if flip { &buf_b[len * n..] } else { &buf_b[..len * n] };
    std::thread::scope(|scope| {
        let mut rest = &mut out[..];
        for &(lo, hi) in &ranges {
            let (chunk_out, tail) = rest.split_at_mut((hi - lo) * n);
            rest = tail;
            scope.spawn(move || {
                for i in lo..hi {
                    let oi = i - lo;
                    let dst = &mut chunk_out[oi * n..(oi + 1) * n];
                    apply_a(st, &half_a[i * jl..(i + 1) * jl], y0, dst, n);
                    for j in 0..n {
                        dst[j] += half_b[i * n + j];
                    }
                }
            });
        }
    });
}

/// Shared reverse driver: dual elements `F_i = (M_i, v_i)` staged by
/// `init`, suffix-swept, vector parts copied out as `λ_i`.
fn cr_reverse_driver<S: Scalar>(
    st: JacobianStructure,
    out: &mut [S],
    n: usize,
    len: usize,
    threads: usize,
    ws: &mut ScanWorkspace<S>,
    init: impl Fn(usize, &mut [S], &mut [S]) + Sync,
) {
    if len == 0 {
        return;
    }
    let jl = st.jac_len(n);
    ws.ensure(2 * len * jl, 2 * len * n, 0);
    let buf_a = &mut ws.comp_a[..2 * len * jl];
    let buf_b = &mut ws.comp_b[..2 * len * n];
    let ranges = worker_ranges(len, threads);
    {
        let (stage_a, _) = buf_a.split_at_mut(len * jl);
        let (stage_b, _) = buf_b.split_at_mut(len * n);
        std::thread::scope(|scope| {
            let mut rest_a = stage_a;
            let mut rest_b = stage_b;
            for &(lo, hi) in &ranges {
                let (ca, ta) = rest_a.split_at_mut((hi - lo) * jl);
                let (cb, tb) = rest_b.split_at_mut((hi - lo) * n);
                rest_a = ta;
                rest_b = tb;
                let init = &init;
                scope.spawn(move || {
                    for i in lo..hi {
                        let oi = i - lo;
                        init(i, &mut ca[oi * jl..(oi + 1) * jl], &mut cb[oi * n..(oi + 1) * n]);
                    }
                });
            }
        });
    }
    let flip = cr_levels(st, n, len, threads, true, buf_a, buf_b);
    let half_b = if flip { &buf_b[len * n..] } else { &buf_b[..len * n] };
    out.copy_from_slice(half_b);
}

/// Stage the structure-transposed next-step Jacobian `M_i = A_{i+1}ᵀ`
/// (beyond-end → 0) into `m_out`, scaled by `s`.
fn stage_dual_m<S: Scalar>(
    st: JacobianStructure,
    a: &[S],
    i: usize,
    len: usize,
    s: S,
    m_out: &mut [S],
    n: usize,
) {
    let jl = st.jac_len(n);
    if i + 1 >= len {
        for v in m_out.iter_mut() {
            *v = S::zero();
        }
        return;
    }
    let a_next = &a[(i + 1) * jl..(i + 2) * jl];
    match st {
        JacobianStructure::Dense => {
            for r in 0..n {
                for c in 0..n {
                    m_out[r * n + c] = s * a_next[c * n + r];
                }
            }
        }
        JacobianStructure::Diagonal => {
            for j in 0..n {
                m_out[j] = s * a_next[j];
            }
        }
        JacobianStructure::Block { k } => {
            for bb in 0..n / k {
                let tile = &a_next[bb * k * k..(bb + 1) * k * k];
                let out_tile = &mut m_out[bb * k * k..(bb + 1) * k * k];
                for r in 0..k {
                    for c in 0..k {
                        out_tile[r * k + c] = s * tile[c * k + r];
                    }
                }
            }
        }
    }
}

/// Dense forward cyclic-reduction scan: `out_i = A_i out_{i−1} + b_i`
/// with `out_{−1} = y0`, in ⌈log₂ len⌉ compose levels.
#[allow(clippy::too_many_arguments)]
pub fn par_scan_apply_cr_ws<S: Scalar>(
    a: &[S],
    b: &[S],
    y0: &[S],
    out: &mut [S],
    n: usize,
    len: usize,
    threads: usize,
    ws: &mut ScanWorkspace<S>,
) {
    cr_apply_driver(JacobianStructure::Dense, y0, out, n, len, threads, ws, |i, ea, eb| {
        ea.copy_from_slice(&a[i * n * n..(i + 1) * n * n]);
        eb.copy_from_slice(&b[i * n..(i + 1) * n]);
    });
}

/// Dense reverse (dual) cyclic-reduction scan:
/// `λ_i = g_i + A_{i+1}ᵀ λ_{i+1}` (beyond-end `A` = 0).
#[allow(clippy::too_many_arguments)]
pub fn par_scan_reverse_cr_ws<S: Scalar>(
    a: &[S],
    g: &[S],
    out: &mut [S],
    n: usize,
    len: usize,
    threads: usize,
    ws: &mut ScanWorkspace<S>,
) {
    let st = JacobianStructure::Dense;
    cr_reverse_driver(st, out, n, len, threads, ws, |i, ma, vb| {
        stage_dual_m(st, a, i, len, S::one(), ma, n);
        vb.copy_from_slice(&g[i * n..(i + 1) * n]);
    });
}

/// Diagonal forward cyclic-reduction scan (packed diagonals).
#[allow(clippy::too_many_arguments)]
pub fn par_diag_scan_apply_cr_ws<S: Scalar>(
    a: &[S],
    b: &[S],
    y0: &[S],
    out: &mut [S],
    n: usize,
    len: usize,
    threads: usize,
    ws: &mut ScanWorkspace<S>,
) {
    cr_apply_driver(JacobianStructure::Diagonal, y0, out, n, len, threads, ws, |i, ea, eb| {
        ea.copy_from_slice(&a[i * n..(i + 1) * n]);
        eb.copy_from_slice(&b[i * n..(i + 1) * n]);
    });
}

/// Diagonal reverse (dual) cyclic-reduction scan (transpose is a no-op).
#[allow(clippy::too_many_arguments)]
pub fn par_diag_scan_reverse_cr_ws<S: Scalar>(
    a: &[S],
    g: &[S],
    out: &mut [S],
    n: usize,
    len: usize,
    threads: usize,
    ws: &mut ScanWorkspace<S>,
) {
    let st = JacobianStructure::Diagonal;
    cr_reverse_driver(st, out, n, len, threads, ws, |i, ma, vb| {
        stage_dual_m(st, a, i, len, S::one(), ma, n);
        vb.copy_from_slice(&g[i * n..(i + 1) * n]);
    });
}

/// Block-diagonal forward cyclic-reduction scan (packed k×k tiles).
#[allow(clippy::too_many_arguments)]
pub fn par_block_scan_apply_cr_ws<S: Scalar>(
    a: &[S],
    b: &[S],
    y0: &[S],
    out: &mut [S],
    n: usize,
    k: usize,
    len: usize,
    threads: usize,
    ws: &mut ScanWorkspace<S>,
) {
    let st = JacobianStructure::Block { k };
    let jl = st.jac_len(n);
    cr_apply_driver(st, y0, out, n, len, threads, ws, |i, ea, eb| {
        ea.copy_from_slice(&a[i * jl..(i + 1) * jl]);
        eb.copy_from_slice(&b[i * n..(i + 1) * n]);
    });
}

/// Block-diagonal reverse (dual) cyclic-reduction scan (per-tile
/// transpose).
#[allow(clippy::too_many_arguments)]
pub fn par_block_scan_reverse_cr_ws<S: Scalar>(
    a: &[S],
    g: &[S],
    out: &mut [S],
    n: usize,
    k: usize,
    len: usize,
    threads: usize,
    ws: &mut ScanWorkspace<S>,
) {
    let st = JacobianStructure::Block { k };
    cr_reverse_driver(st, out, n, len, threads, ws, |i, ma, vb| {
        stage_dual_m(st, a, i, len, S::one(), ma, n);
        vb.copy_from_slice(&g[i * n..(i + 1) * n]);
    });
}

/// Damped (Kalman) forward cyclic-reduction scan over the scaled elements
/// `(s·A_i, s·(b_i + λ z_i))`, `s = 1/(1+λ)`. At λ = 0 routes to the plain
/// CR kernel of `structure` bit-for-bit (the [`super::kalman`] contract).
#[allow(clippy::too_many_arguments)]
pub fn par_kalman_scan_apply_cr_ws<S: Scalar>(
    a: &[S],
    b: &[S],
    z: &[S],
    y0: &[S],
    out: &mut [S],
    n: usize,
    structure: JacobianStructure,
    len: usize,
    lambda: S,
    threads: usize,
    ws: &mut ScanWorkspace<S>,
) {
    if lambda == S::zero() {
        match structure {
            JacobianStructure::Dense => par_scan_apply_cr_ws(a, b, y0, out, n, len, threads, ws),
            JacobianStructure::Diagonal => {
                par_diag_scan_apply_cr_ws(a, b, y0, out, n, len, threads, ws)
            }
            JacobianStructure::Block { k } => {
                par_block_scan_apply_cr_ws(a, b, y0, out, n, k, len, threads, ws)
            }
        }
        return;
    }
    let s = damp_gain(lambda);
    let jl = structure.jac_len(n);
    cr_apply_driver(structure, y0, out, n, len, threads, ws, |i, ea, eb| {
        for (q, v) in ea.iter_mut().enumerate() {
            *v = s * a[i * jl + q];
        }
        for (j, v) in eb.iter_mut().enumerate() {
            *v = s * (b[i * n + j] + lambda * z[i * n + j]);
        }
    });
}

/// Damped (Kalman) reverse cyclic-reduction scan over the scaled dual
/// elements `(s·A_{i+1}ᵀ, s·g_i)`. At λ = 0 routes to the plain CR
/// reverse kernel of `structure` bit-for-bit.
#[allow(clippy::too_many_arguments)]
pub fn par_kalman_scan_reverse_cr_ws<S: Scalar>(
    a: &[S],
    g: &[S],
    out: &mut [S],
    n: usize,
    structure: JacobianStructure,
    len: usize,
    lambda: S,
    threads: usize,
    ws: &mut ScanWorkspace<S>,
) {
    if lambda == S::zero() {
        match structure {
            JacobianStructure::Dense => par_scan_reverse_cr_ws(a, g, out, n, len, threads, ws),
            JacobianStructure::Diagonal => {
                par_diag_scan_reverse_cr_ws(a, g, out, n, len, threads, ws)
            }
            JacobianStructure::Block { k } => {
                par_block_scan_reverse_cr_ws(a, g, out, n, k, len, threads, ws)
            }
        }
        return;
    }
    let s = damp_gain(lambda);
    cr_reverse_driver(structure, out, n, len, threads, ws, |i, ma, vb| {
        stage_dual_m(structure, a, i, len, s, ma, n);
        for (j, v) in vb.iter_mut().enumerate() {
            *v = s * g[i * n + j];
        }
    });
}

#[cfg(test)]
mod tests {
    use super::super::{
        seq_block_scan_apply, seq_block_scan_reverse, seq_diag_scan_apply, seq_diag_scan_reverse,
        seq_kalman_scan_apply, seq_kalman_scan_reverse, seq_scan_apply, seq_scan_reverse,
    };
    use super::*;
    use crate::util::rng::Rng;

    const LENS: [usize; 8] = [1, 2, 3, 5, 7, 31, 33, 100];
    const THREADS: [usize; 4] = [1, 2, 4, 8];

    fn rand_vec(rng: &mut Rng, len: usize, scale: f64) -> Vec<f64> {
        let mut v = vec![0.0; len];
        rng.fill_normal(&mut v, scale);
        v
    }

    /// Forward CR must agree with the sequential replay for every
    /// structure, at awkward (non-power-of-two) lengths and all thread
    /// counts. Not bitwise — CR associates differently — so tolerance.
    #[test]
    fn cr_apply_matches_seq_all_structures() {
        let n = 4;
        for &len in &LENS {
            let mut rng = Rng::new(500 + len as u64);
            let da = rand_vec(&mut rng, len * n * n, 0.5);
            let ga = rand_vec(&mut rng, len * n, 0.5);
            let ba = rand_vec(&mut rng, len * n * 2, 0.5);
            let b = rand_vec(&mut rng, len * n, 1.0);
            let y0 = rand_vec(&mut rng, n, 1.0);

            let mut want_d = vec![0.0; len * n];
            seq_scan_apply(&da, &b, &y0, &mut want_d, n, len);
            let mut want_g = vec![0.0; len * n];
            seq_diag_scan_apply(&ga, &b, &y0, &mut want_g, n, len);
            let mut want_b = vec![0.0; len * n];
            seq_block_scan_apply(&ba, &b, &y0, &mut want_b, n, 2, len);

            for &threads in &THREADS {
                let mut ws = ScanWorkspace::new();
                let mut out = vec![0.0; len * n];
                par_scan_apply_cr_ws(&da, &b, &y0, &mut out, n, len, threads, &mut ws);
                for i in 0..len * n {
                    assert!((out[i] - want_d[i]).abs() < 1e-10, "dense len={len} t={threads} i={i}");
                }
                par_diag_scan_apply_cr_ws(&ga, &b, &y0, &mut out, n, len, threads, &mut ws);
                for i in 0..len * n {
                    assert!((out[i] - want_g[i]).abs() < 1e-10, "diag len={len} t={threads} i={i}");
                }
                par_block_scan_apply_cr_ws(&ba, &b, &y0, &mut out, n, 2, len, threads, &mut ws);
                for i in 0..len * n {
                    assert!((out[i] - want_b[i]).abs() < 1e-10, "block len={len} t={threads} i={i}");
                }
            }
        }
    }

    /// Reverse-dual CR must agree with the sequential dual replay for
    /// every structure across the same length/thread grid.
    #[test]
    fn cr_reverse_matches_seq_all_structures() {
        let n = 4;
        for &len in &LENS {
            let mut rng = Rng::new(600 + len as u64);
            let da = rand_vec(&mut rng, len * n * n, 0.5);
            let ga = rand_vec(&mut rng, len * n, 0.5);
            let ba = rand_vec(&mut rng, len * n * 2, 0.5);
            let g = rand_vec(&mut rng, len * n, 1.0);

            let mut want_d = vec![0.0; len * n];
            seq_scan_reverse(&da, &g, &mut want_d, n, len);
            let mut want_g = vec![0.0; len * n];
            seq_diag_scan_reverse(&ga, &g, &mut want_g, n, len);
            let mut want_b = vec![0.0; len * n];
            seq_block_scan_reverse(&ba, &g, &mut want_b, n, 2, len);

            for &threads in &THREADS {
                let mut ws = ScanWorkspace::new();
                let mut out = vec![0.0; len * n];
                par_scan_reverse_cr_ws(&da, &g, &mut out, n, len, threads, &mut ws);
                for i in 0..len * n {
                    assert!((out[i] - want_d[i]).abs() < 1e-10, "dense len={len} t={threads} i={i}");
                }
                par_diag_scan_reverse_cr_ws(&ga, &g, &mut out, n, len, threads, &mut ws);
                for i in 0..len * n {
                    assert!((out[i] - want_g[i]).abs() < 1e-10, "diag len={len} t={threads} i={i}");
                }
                par_block_scan_reverse_cr_ws(&ba, &g, &mut out, n, 2, len, threads, &mut ws);
                for i in 0..len * n {
                    assert!((out[i] - want_b[i]).abs() < 1e-10, "block len={len} t={threads} i={i}");
                }
            }
        }
    }

    /// Damped CR forward + reverse agree with the sequential damped
    /// kernels; λ = 0 is bitwise equal to the plain CR kernels.
    #[test]
    fn cr_kalman_matches_seq_damped() {
        let structs = [
            JacobianStructure::Dense,
            JacobianStructure::Diagonal,
            JacobianStructure::Block { k: 2 },
        ];
        let n = 4;
        let len = 37;
        let lambda = 0.7;
        for st in structs {
            let jl = st.jac_len(n);
            let mut rng = Rng::new(700);
            let a = rand_vec(&mut rng, len * jl, 0.5);
            let b = rand_vec(&mut rng, len * n, 1.0);
            let z = rand_vec(&mut rng, len * n, 1.0);
            let g = rand_vec(&mut rng, len * n, 1.0);
            let y0 = rand_vec(&mut rng, n, 1.0);

            let mut want = vec![0.0; len * n];
            seq_kalman_scan_apply(&a, &b, &z, &y0, &mut want, n, st, len, lambda);
            let mut want_rev = vec![0.0; len * n];
            seq_kalman_scan_reverse(&a, &g, &mut want_rev, n, st, len, lambda);

            for threads in [2, 8] {
                let mut ws = ScanWorkspace::new();
                let mut out = vec![0.0; len * n];
                par_kalman_scan_apply_cr_ws(
                    &a, &b, &z, &y0, &mut out, n, st, len, lambda, threads, &mut ws,
                );
                for i in 0..len * n {
                    assert!((out[i] - want[i]).abs() < 1e-10, "{st:?} fwd t={threads} i={i}");
                }
                par_kalman_scan_reverse_cr_ws(
                    &a, &g, &mut out, n, st, len, lambda, threads, &mut ws,
                );
                for i in 0..len * n {
                    assert!((out[i] - want_rev[i]).abs() < 1e-10, "{st:?} rev t={threads} i={i}");
                }
            }

            // λ = 0 routes to the plain CR kernels bit-for-bit.
            let mut ws = ScanWorkspace::new();
            let mut damped = vec![0.0; len * n];
            par_kalman_scan_apply_cr_ws(
                &a, &b, &z, &y0, &mut damped, n, st, len, 0.0, 4, &mut ws,
            );
            let mut plain = vec![0.0; len * n];
            match st {
                JacobianStructure::Dense => {
                    par_scan_apply_cr_ws(&a, &b, &y0, &mut plain, n, len, 4, &mut ws)
                }
                JacobianStructure::Diagonal => {
                    par_diag_scan_apply_cr_ws(&a, &b, &y0, &mut plain, n, len, 4, &mut ws)
                }
                JacobianStructure::Block { k } => {
                    par_block_scan_apply_cr_ws(&a, &b, &y0, &mut plain, n, k, len, 4, &mut ws)
                }
            }
            assert_eq!(plain, damped, "{st:?} λ=0 CR bitwise");
        }
    }

    /// The associativity property the CR schedule relies on, exercised
    /// through the schedule itself: folding the same random elements
    /// left-to-right (sequential association) and through the CR doubling
    /// tree must produce the same prefix element, for every structure's
    /// combine. Checked at the element level by probing the composed
    /// affine map with basis initial states.
    #[test]
    fn cr_schedule_associativity_property() {
        let n = 3;
        for &len in &[6usize, 9, 16, 29] {
            let mut rng = Rng::new(800 + len as u64);
            let a = rand_vec(&mut rng, len * n * n, 0.6);
            let b = rand_vec(&mut rng, len * n, 1.0);
            // Probe with the n basis vectors plus 0: reconstructs the full
            // composed (A', b') of the final prefix element.
            let mut probes: Vec<Vec<f64>> = (0..n)
                .map(|j| (0..n).map(|i| if i == j { 1.0 } else { 0.0 }).collect())
                .collect();
            probes.push(vec![0.0; n]);
            for y0 in &probes {
                let mut want = vec![0.0; len * n];
                seq_scan_apply(&a, &b, y0, &mut want, n, len);
                let mut ws = ScanWorkspace::new();
                let mut got = vec![0.0; len * n];
                par_scan_apply_cr_ws(&a, &b, y0, &mut got, n, len, 4, &mut ws);
                // Only the final element pins the fully-composed prefix;
                // intermediate ones pin every partial prefix.
                for i in 0..len * n {
                    assert!((got[i] - want[i]).abs() < 1e-10, "len={len} i={i}");
                }
            }
        }
    }

    /// CR reuses a workspace across calls of different sizes without
    /// contamination (buffers only grow; stale halves never leak).
    #[test]
    fn cr_workspace_reuse_across_sizes() {
        let n = 4;
        let mut ws = ScanWorkspace::new();
        for &len in &[64usize, 5, 33, 1] {
            let mut rng = Rng::new(900 + len as u64);
            let a = rand_vec(&mut rng, len * n, 0.5);
            let b = rand_vec(&mut rng, len * n, 1.0);
            let y0 = rand_vec(&mut rng, n, 1.0);
            let mut want = vec![0.0; len * n];
            seq_diag_scan_apply(&a, &b, &y0, &mut want, n, len);
            let mut out = vec![0.0; len * n];
            par_diag_scan_apply_cr_ws(&a, &b, &y0, &mut out, n, len, 4, &mut ws);
            for i in 0..len * n {
                assert!((out[i] - want[i]).abs() < 1e-10, "len={len} i={i}");
            }
        }
    }
}
