//! Sequential evaluation of the affine recurrence (and its dual).

use crate::util::scalar::Scalar;

/// `out[i] = A_i · y_{i−1} + b_i` with `y_{−1} = y0`; `out` has `len·n`.
///
/// This is the work-optimal O(n²·L) evaluation used (a) inside each chunk of
/// the parallel scan's phase 3 and (b) by the sequential DEER baseline's
/// `L_G⁻¹`.
pub fn seq_scan_apply<S: Scalar>(a: &[S], b: &[S], y0: &[S], out: &mut [S], n: usize, len: usize) {
    debug_assert_eq!(a.len(), len * n * n);
    debug_assert_eq!(b.len(), len * n);
    debug_assert_eq!(out.len(), len * n);
    if len == 0 {
        return;
    }
    if n == 1 {
        // scalar fast path
        let mut prev = y0[0];
        for i in 0..len {
            prev = a[i] * prev + b[i];
            out[i] = prev;
        }
        return;
    }
    // first element from y0
    {
        let a0 = &a[..n * n];
        let (head, _) = out.split_at_mut(n);
        crate::linalg::matvec(a0, y0, head);
        for j in 0..n {
            head[j] += b[j];
        }
    }
    for i in 1..len {
        let (prev_part, cur_part) = out.split_at_mut(i * n);
        let prev = &prev_part[(i - 1) * n..];
        let cur = &mut cur_part[..n];
        let ai = &a[i * n * n..(i + 1) * n * n];
        crate::linalg::matvec(ai, prev, cur);
        let bi = &b[i * n..(i + 1) * n];
        for j in 0..n {
            cur[j] += bi[j];
        }
    }
}

/// Dual (reverse, transposed) recurrence of the DEER backward pass (eq. 7):
///
/// `λ_i = g_i + A_{i+1}ᵀ · λ_{i+1}`, `λ_{L−1} = g_{L−1}`.
///
/// `a[i]` is the Jacobian propagating step i−1 → i (same layout as the
/// forward scan), so position i uses `a[i+1]`.
pub fn seq_scan_reverse<S: Scalar>(a: &[S], g: &[S], out: &mut [S], n: usize, len: usize) {
    debug_assert_eq!(a.len(), len * n * n);
    debug_assert_eq!(g.len(), len * n);
    debug_assert_eq!(out.len(), len * n);
    if len == 0 {
        return;
    }
    if n == 1 {
        let mut next = g[len - 1];
        out[len - 1] = next;
        for i in (0..len - 1).rev() {
            next = g[i] + a[i + 1] * next;
            out[i] = next;
        }
        return;
    }
    out[(len - 1) * n..].copy_from_slice(&g[(len - 1) * n..]);
    let mut tmp = vec![S::zero(); n];
    for i in (0..len - 1).rev() {
        let a_next = &a[(i + 1) * n * n..(i + 2) * n * n];
        let (cur_part, next_part) = out.split_at_mut((i + 1) * n);
        let next = &next_part[..n];
        crate::linalg::matvec_t(a_next, next, &mut tmp);
        let cur = &mut cur_part[i * n..];
        let gi = &g[i * n..(i + 1) * n];
        for j in 0..n {
            cur[j] = gi[j] + tmp[j];
        }
    }
}

/// Compose a contiguous range of elements into a single `(A, b)` pair:
/// `A = A_{hi−1}···A_{lo}`, `b` the matching offset. O(n³·(hi−lo)).
pub fn compose_range<S: Scalar>(
    a: &[S],
    b: &[S],
    lo: usize,
    hi: usize,
    a_out: &mut [S],
    b_out: &mut [S],
    n: usize,
) {
    crate::linalg::eye_into(a_out, n);
    for v in b_out.iter_mut() {
        *v = S::zero();
    }
    let mut tmp_a = vec![S::zero(); n * n];
    let mut tmp_b = vec![S::zero(); n];
    for i in lo..hi {
        let ai = &a[i * n * n..(i + 1) * n * n];
        let bi = &b[i * n..(i + 1) * n];
        // (A_i, b_i) ∘ (A_out, b_out)
        crate::linalg::matmul(ai, a_out, &mut tmp_a, n);
        crate::linalg::matvec(ai, b_out, &mut tmp_b);
        a_out.copy_from_slice(&tmp_a);
        for j in 0..n {
            b_out[j] = tmp_b[j] + bi[j];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_seq(n: usize, len: usize, seed: u64) -> (Vec<f64>, Vec<f64>, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let mut a = vec![0.0; len * n * n];
        let mut b = vec![0.0; len * n];
        let mut y0 = vec![0.0; n];
        rng.fill_normal(&mut a, 0.5);
        rng.fill_normal(&mut b, 1.0);
        rng.fill_normal(&mut y0, 1.0);
        (a, b, y0)
    }

    #[test]
    fn matches_naive_recurrence() {
        let (n, len) = (3, 17);
        let (a, b, y0) = random_seq(n, len, 1);
        let mut out = vec![0.0; len * n];
        seq_scan_apply(&a, &b, &y0, &mut out, n, len);

        let mut y = y0.clone();
        for i in 0..len {
            let mut ynew = vec![0.0; n];
            crate::linalg::matvec(&a[i * n * n..(i + 1) * n * n], &y, &mut ynew);
            for j in 0..n {
                ynew[j] += b[i * n + j];
            }
            for j in 0..n {
                assert!((out[i * n + j] - ynew[j]).abs() < 1e-12);
            }
            y = ynew;
        }
    }

    #[test]
    fn scalar_fast_path_matches_general() {
        let (a, b, y0) = random_seq(1, 64, 2);
        let mut out1 = vec![0.0; 64];
        seq_scan_apply(&a, &b, &y0, &mut out1, 1, 64);
        // general path via 2x2 embedding: [[a,0],[0,0]] y + [b,0]
        let mut a2 = vec![0.0; 64 * 4];
        let mut b2 = vec![0.0; 64 * 2];
        for i in 0..64 {
            a2[i * 4] = a[i];
            b2[i * 2] = b[i];
        }
        let mut out2 = vec![0.0; 64 * 2];
        seq_scan_apply(&a2, &b2, &[y0[0], 0.0], &mut out2, 2, 64);
        for i in 0..64 {
            assert!((out1[i] - out2[i * 2]).abs() < 1e-12);
        }
    }

    #[test]
    fn reverse_matches_naive() {
        let (n, len) = (2, 11);
        let (a, g, _) = random_seq(n, len, 3);
        let mut lam = vec![0.0; len * n];
        seq_scan_reverse(&a, &g, &mut lam, n, len);

        // naive
        let mut next = g[(len - 1) * n..].to_vec();
        for j in 0..n {
            assert!((lam[(len - 1) * n + j] - next[j]).abs() < 1e-12);
        }
        for i in (0..len - 1).rev() {
            let a_next = &a[(i + 1) * n * n..(i + 2) * n * n];
            let mut t = vec![0.0; n];
            crate::linalg::matvec_t(a_next, &next, &mut t);
            let cur: Vec<f64> = (0..n).map(|j| g[i * n + j] + t[j]).collect();
            for j in 0..n {
                assert!((lam[i * n + j] - cur[j]).abs() < 1e-12);
            }
            next = cur;
        }
    }

    #[test]
    fn compose_range_equals_endpoint() {
        // Applying the composed transform to y0 == running the scan to hi−1.
        let (n, len) = (3, 9);
        let (a, b, y0) = random_seq(n, len, 4);
        let mut out = vec![0.0; len * n];
        seq_scan_apply(&a, &b, &y0, &mut out, n, len);

        let mut ca = vec![0.0; n * n];
        let mut cb = vec![0.0; n];
        compose_range(&a, &b, 0, len, &mut ca, &mut cb, n);
        let mut y_end = vec![0.0; n];
        crate::linalg::matvec(&ca, &y0, &mut y_end);
        for j in 0..n {
            y_end[j] += cb[j];
        }
        for j in 0..n {
            assert!((y_end[j] - out[(len - 1) * n + j]).abs() < 1e-10);
        }
    }

    #[test]
    fn empty_and_single() {
        let mut out: Vec<f64> = vec![];
        seq_scan_apply::<f64>(&[], &[], &[1.0], &mut out, 1, 0);
        let a = vec![2.0];
        let b = vec![3.0];
        let mut out = vec![0.0];
        seq_scan_apply(&a, &b, &[4.0], &mut out, 1, 1);
        assert_eq!(out, vec![11.0]);
        let mut lam = vec![0.0];
        seq_scan_reverse(&a, &b, &mut lam, 1, 1);
        assert_eq!(lam, vec![3.0]);
    }
}
