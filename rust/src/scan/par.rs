//! Multi-threaded chunked prefix scan (dense n×n elements).
//!
//! Three-phase structure (the classic work-efficient decomposition, and the
//! same schedule the L1 Pallas kernel expresses with BlockSpec over sequence
//! blocks):
//!
//! 1. **Compose** — each of C chunks reduces its elements into a single
//!    affine pair `(A_c, b_c)` (O(n³·L/C) per worker, fully parallel).
//! 2. **Carry** — a sequential scan over the C chunk pairs produces the
//!    entry state of every chunk (O(n²·C), negligible for C ≪ L).
//! 3. **Apply** — each chunk replays the cheap O(n²) recurrence from its
//!    entry state (fully parallel).
//!
//! On this single-core testbed the thread count is a *model* of accelerator
//! lanes: wall-clock parity is expected at T=1 while the [`crate::simulator`]
//! converts the phase work/depth into projected accelerator time. On a
//! multi-core host the same code yields real speedups.
//!
//! The `*_ws` variants take a caller-owned [`ScanWorkspace`] so repeated
//! invocations (the Newton loop) allocate nothing; the plain variants
//! allocate a throwaway workspace for one-shot use.

use super::cr::{par_scan_apply_cr_ws, par_scan_reverse_cr_ws};
use super::seq::{compose_range, seq_scan_apply, seq_scan_reverse};
use super::{choose_scan_schedule_observed, flops_apply, flops_combine, ScanSchedule, ScanWorkspace};
use crate::util::scalar::Scalar;

/// Parallel `y_i = A_i y_{i−1} + b_i` over `threads` workers.
///
/// Falls back to [`seq_scan_apply`] when `threads <= 1` or the sequence is
/// too short to amortize chunking.
pub fn par_scan_apply<S: Scalar>(
    a: &[S],
    b: &[S],
    y0: &[S],
    out: &mut [S],
    n: usize,
    len: usize,
    threads: usize,
) {
    let mut ws = ScanWorkspace::new();
    par_scan_apply_ws(a, b, y0, out, n, len, threads, &mut ws);
}

/// [`par_scan_apply`] with a reusable workspace (no per-call allocation).
#[allow(clippy::too_many_arguments)]
pub fn par_scan_apply_ws<S: Scalar>(
    a: &[S],
    b: &[S],
    y0: &[S],
    out: &mut [S],
    n: usize,
    len: usize,
    threads: usize,
    ws: &mut ScanWorkspace<S>,
) {
    match choose_scan_schedule_observed(len, threads, flops_combine(n), flops_apply(n, 1)) {
        ScanSchedule::Sequential => {
            seq_scan_apply(a, b, y0, out, n, len);
            return;
        }
        ScanSchedule::CyclicReduction => {
            par_scan_apply_cr_ws(a, b, y0, out, n, len, threads, ws);
            return;
        }
        ScanSchedule::Chunked => {}
    }
    let chunks = threads;
    let chunk_len = len.div_ceil(chunks);
    let nn = n * n;
    ws.ensure(chunks * nn, chunks * n, chunks * n);

    // Phase 1: per-chunk composition, in parallel.
    {
        let comp: Vec<(&mut [S], &mut [S])> = ws.comp_a[..chunks * nn]
            .chunks_mut(nn)
            .zip(ws.comp_b[..chunks * n].chunks_mut(n))
            .collect();
        std::thread::scope(|scope| {
            for (c, (ca, cb)) in comp.into_iter().enumerate() {
                let lo = (c * chunk_len).min(len);
                let hi = ((c + 1) * chunk_len).min(len);
                scope.spawn(move || {
                    compose_range(a, b, lo, hi, ca, cb, n);
                });
            }
        });
    }

    // Phase 2: sequential carry over chunk entry states.
    // carry[c] = state before chunk c (i.e. y at index c*chunk_len − 1).
    let (comp_a, comp_b) = (&ws.comp_a, &ws.comp_b);
    let entries = &mut ws.carry[..chunks * n];
    entries[..n].copy_from_slice(y0);
    for c in 0..chunks - 1 {
        let (head, tail) = entries.split_at_mut((c + 1) * n);
        let prev = &head[c * n..];
        let next = &mut tail[..n];
        crate::linalg::matvec(&comp_a[c * nn..(c + 1) * nn], prev, next);
        for j in 0..n {
            next[j] += comp_b[c * n + j];
        }
    }

    // Phase 3: per-chunk apply, in parallel.
    {
        let entries = &ws.carry;
        let mut out_chunks: Vec<&mut [S]> = Vec::with_capacity(chunks);
        let mut rest = out;
        for c in 0..chunks {
            let lo = (c * chunk_len).min(len);
            let hi = ((c + 1) * chunk_len).min(len);
            let (head, tail) = rest.split_at_mut((hi - lo) * n);
            out_chunks.push(head);
            rest = tail;
        }
        std::thread::scope(|scope| {
            for (c, out_c) in out_chunks.into_iter().enumerate() {
                let lo = (c * chunk_len).min(len);
                let hi = ((c + 1) * chunk_len).min(len);
                let entry = &entries[c * n..(c + 1) * n];
                scope.spawn(move || {
                    seq_scan_apply(
                        &a[lo * nn..hi * nn],
                        &b[lo * n..hi * n],
                        entry,
                        out_c,
                        n,
                        hi - lo,
                    );
                });
            }
        });
    }
}

/// Fused batched forward scan over B independent sequences in the
/// `[B, T, n²]` / `[B, T, n]` layout (see the batched-layout notes in
/// [`crate::scan`]): one call schedules the whole B×T element grid across
/// `threads` workers. `active` (length B) masks sequences in place —
/// masked-out slabs of `out` are neither read nor written.
///
/// Scheduling: with B ≥ threads each worker runs the plain sequential
/// kernel over whole sequences (no redundant compose work); with
/// B < threads the spare lanes split inside sequences via the three-phase
/// chunked scan. All scheduling is keyed on the total B, never the active
/// count, so results are bit-reproducible across masking states.
#[allow(clippy::too_many_arguments)]
pub fn par_scan_apply_batch_ws<S: Scalar>(
    a: &[S],
    b: &[S],
    y0s: &[S],
    out: &mut [S],
    n: usize,
    t_len: usize,
    batch: usize,
    active: Option<&[bool]>,
    threads: usize,
    ws: &mut ScanWorkspace<S>,
) {
    let nn = n * n;
    debug_assert_eq!(a.len(), batch * t_len * nn);
    debug_assert_eq!(b.len(), batch * t_len * n);
    debug_assert_eq!(y0s.len(), batch * n);
    debug_assert_eq!(out.len(), batch * t_len * n);
    let idx = super::active_indices(batch, active);
    if idx.is_empty() || t_len == 0 {
        return;
    }
    let sa = t_len * nn;
    let sb = t_len * n;
    if batch == 1 {
        // the single-sequence case: intra-sequence three-phase scan with the
        // caller's reusable workspace
        par_scan_apply_ws(a, b, y0s, out, n, t_len, threads, ws);
        return;
    }
    // Scheduling is keyed on the TOTAL batch size (not the active count) so
    // a sequence's accumulation order never changes as neighbours freeze —
    // batched results stay bit-reproducible across masking states.
    let mut slabs: Vec<Option<&mut [S]>> = out.chunks_mut(sb).map(Some).collect();
    if threads <= 1 {
        for &s in &idx {
            let o = slabs[s].take().unwrap();
            seq_scan_apply(
                &a[s * sa..(s + 1) * sa],
                &b[s * sb..(s + 1) * sb],
                &y0s[s * n..(s + 1) * n],
                o,
                n,
                t_len,
            );
        }
    } else if batch >= threads {
        let workers = threads.min(idx.len());
        let mut buckets: Vec<Vec<(usize, &mut [S])>> = (0..workers).map(|_| Vec::new()).collect();
        for (k, &s) in idx.iter().enumerate() {
            buckets[k % workers].push((s, slabs[s].take().unwrap()));
        }
        std::thread::scope(|scope| {
            for bucket in buckets {
                scope.spawn(move || {
                    for (s, o) in bucket {
                        seq_scan_apply(
                            &a[s * sa..(s + 1) * sa],
                            &b[s * sb..(s + 1) * sb],
                            &y0s[s * n..(s + 1) * n],
                            o,
                            n,
                            t_len,
                        );
                    }
                });
            }
        });
    } else {
        // 1 < B < threads: fixed intra-sequence split (constant divisor B
        // keeps the decomposition masking-invariant)
        let cps = (threads / batch).max(2);
        std::thread::scope(|scope| {
            for &s in &idx {
                let o = slabs[s].take().unwrap();
                let a_s = &a[s * sa..(s + 1) * sa];
                let b_s = &b[s * sb..(s + 1) * sb];
                let y0_s = &y0s[s * n..(s + 1) * n];
                scope.spawn(move || {
                    let mut local = ScanWorkspace::new();
                    par_scan_apply_ws(a_s, b_s, y0_s, o, n, t_len, cps, &mut local);
                });
            }
        });
    }
}

/// Fused batched dual scan (`[B, T, n…]` layout; same scheduling and masking
/// rules as [`par_scan_apply_batch_ws`]).
#[allow(clippy::too_many_arguments)]
pub fn par_scan_reverse_batch_ws<S: Scalar>(
    a: &[S],
    g: &[S],
    out: &mut [S],
    n: usize,
    t_len: usize,
    batch: usize,
    active: Option<&[bool]>,
    threads: usize,
    ws: &mut ScanWorkspace<S>,
) {
    let nn = n * n;
    debug_assert_eq!(a.len(), batch * t_len * nn);
    debug_assert_eq!(g.len(), batch * t_len * n);
    debug_assert_eq!(out.len(), batch * t_len * n);
    let idx = super::active_indices(batch, active);
    if idx.is_empty() || t_len == 0 {
        return;
    }
    let sa = t_len * nn;
    let sb = t_len * n;
    if batch == 1 {
        par_scan_reverse_ws(a, g, out, n, t_len, threads, ws);
        return;
    }
    let mut slabs: Vec<Option<&mut [S]>> = out.chunks_mut(sb).map(Some).collect();
    if threads <= 1 {
        for &s in &idx {
            let o = slabs[s].take().unwrap();
            seq_scan_reverse(&a[s * sa..(s + 1) * sa], &g[s * sb..(s + 1) * sb], o, n, t_len);
        }
    } else if batch >= threads {
        let workers = threads.min(idx.len());
        let mut buckets: Vec<Vec<(usize, &mut [S])>> = (0..workers).map(|_| Vec::new()).collect();
        for (k, &s) in idx.iter().enumerate() {
            buckets[k % workers].push((s, slabs[s].take().unwrap()));
        }
        std::thread::scope(|scope| {
            for bucket in buckets {
                scope.spawn(move || {
                    for (s, o) in bucket {
                        seq_scan_reverse(
                            &a[s * sa..(s + 1) * sa],
                            &g[s * sb..(s + 1) * sb],
                            o,
                            n,
                            t_len,
                        );
                    }
                });
            }
        });
    } else {
        let cps = (threads / batch).max(2);
        std::thread::scope(|scope| {
            for &s in &idx {
                let o = slabs[s].take().unwrap();
                let a_s = &a[s * sa..(s + 1) * sa];
                let g_s = &g[s * sb..(s + 1) * sb];
                scope.spawn(move || {
                    let mut local = ScanWorkspace::new();
                    par_scan_reverse_ws(a_s, g_s, o, n, t_len, cps, &mut local);
                });
            }
        });
    }
}

/// Parallel dual scan `λ_i = g_i + A_{i+1}ᵀ λ_{i+1}` (backward pass, eq. 7).
///
/// Same three-phase structure run right-to-left with transposed matrices.
pub fn par_scan_reverse<S: Scalar>(
    a: &[S],
    g: &[S],
    out: &mut [S],
    n: usize,
    len: usize,
    threads: usize,
) {
    let mut ws = ScanWorkspace::new();
    par_scan_reverse_ws(a, g, out, n, len, threads, &mut ws);
}

/// [`par_scan_reverse`] with a reusable workspace (no per-call allocation).
pub fn par_scan_reverse_ws<S: Scalar>(
    a: &[S],
    g: &[S],
    out: &mut [S],
    n: usize,
    len: usize,
    threads: usize,
    ws: &mut ScanWorkspace<S>,
) {
    match choose_scan_schedule_observed(len, threads, flops_combine(n), flops_apply(n, 1)) {
        ScanSchedule::Sequential => {
            seq_scan_reverse(a, g, out, n, len);
            return;
        }
        ScanSchedule::CyclicReduction => {
            par_scan_reverse_cr_ws(a, g, out, n, len, threads, ws);
            return;
        }
        ScanSchedule::Chunked => {}
    }
    let chunks = threads;
    let chunk_len = len.div_ceil(chunks);
    let nn = n * n;
    ws.ensure(chunks * nn, chunks * n, chunks * n);

    // Phase 1: per-chunk reverse composition.
    // For chunk [lo, hi): λ_{lo} = M_c λ_{hi} + v_c where M_c composes the
    // transposed propagators and v_c the g contributions. Build by iterating
    // i from hi−1 down to lo: λ_i = g_i + A_{i+1}ᵀ λ_{i+1}.
    {
        let comp: Vec<(&mut [S], &mut [S])> = ws.comp_a[..chunks * nn]
            .chunks_mut(nn)
            .zip(ws.comp_b[..chunks * n].chunks_mut(n))
            .collect();
        std::thread::scope(|scope| {
            for (c, (cm, cv)) in comp.into_iter().enumerate() {
                let lo = (c * chunk_len).min(len);
                let hi = ((c + 1) * chunk_len).min(len);
                scope.spawn(move || {
                    // Identity transform to start (λ_hi passes through).
                    crate::linalg::eye_into(cm, n);
                    for v in cv.iter_mut() {
                        *v = S::zero();
                    }
                    let mut tm = vec![S::zero(); nn];
                    let mut tv = vec![S::zero(); n];
                    for i in (lo..hi).rev() {
                        // λ_i = g_i + A_{i+1}ᵀ λ_{i+1}; A beyond len−1 treated as 0
                        if i + 1 < len {
                            let an = &a[(i + 1) * nn..(i + 2) * nn];
                            // new M = A_{i+1}ᵀ · M ; new v = A_{i+1}ᵀ v + g_i
                            // (transposed multiply)
                            for r in 0..n {
                                for ccol in 0..n {
                                    let mut acc = S::zero();
                                    for k in 0..n {
                                        acc += an[k * n + r] * cm[k * n + ccol];
                                    }
                                    tm[r * n + ccol] = acc;
                                }
                            }
                            crate::linalg::matvec_t(an, cv, &mut tv);
                            cm.copy_from_slice(&tm);
                            for j in 0..n {
                                cv[j] = tv[j] + g[i * n + j];
                            }
                        } else {
                            // last element of the whole sequence: λ = g only
                            for v in cm.iter_mut() {
                                *v = S::zero();
                            }
                            cv.copy_from_slice(&g[i * n..(i + 1) * n]);
                        }
                    }
                });
            }
        });
    }

    // Phase 2: carry λ at chunk boundaries, right to left.
    // carry[c] = λ at index hi_c (i.e. entry of chunk c+1), with carry for
    // the last chunk = 0 (no elements beyond the end).
    let (comp_m, comp_v) = (&ws.comp_a, &ws.comp_b);
    let exits = &mut ws.carry[..chunks * n];
    for v in exits[(chunks - 1) * n..].iter_mut() {
        *v = S::zero();
    }
    for c in (1..chunks).rev() {
        // λ_{lo_c} = M_c·exit_c + v_c becomes the exit of chunk c−1.
        let (head, tail) = exits.split_at_mut(c * n);
        let cur = &tail[..n];
        let prev = &mut head[(c - 1) * n..];
        crate::linalg::matvec(&comp_m[c * nn..(c + 1) * nn], cur, prev);
        for j in 0..n {
            prev[j] += comp_v[c * n + j];
        }
    }

    // Phase 3: per-chunk reverse apply.
    {
        let exits = &ws.carry;
        let mut out_chunks: Vec<&mut [S]> = Vec::with_capacity(chunks);
        let mut rest = out;
        for c in 0..chunks {
            let lo = (c * chunk_len).min(len);
            let hi = ((c + 1) * chunk_len).min(len);
            let (head, tail) = rest.split_at_mut((hi - lo) * n);
            out_chunks.push(head);
            rest = tail;
        }
        std::thread::scope(|scope| {
            for (c, out_c) in out_chunks.into_iter().enumerate() {
                let lo = (c * chunk_len).min(len);
                let hi = ((c + 1) * chunk_len).min(len);
                let exit = &exits[c * n..(c + 1) * n];
                scope.spawn(move || {
                    let mut next = exit.to_vec();
                    let mut tmp = vec![S::zero(); n];
                    for i in (lo..hi).rev() {
                        let li = i - lo;
                        if i + 1 < len {
                            let an = &a[(i + 1) * nn..(i + 2) * nn];
                            crate::linalg::matvec_t(an, &next, &mut tmp);
                            for j in 0..n {
                                out_c[li * n + j] = g[i * n + j] + tmp[j];
                            }
                        } else {
                            out_c[li * n..(li + 1) * n]
                                .copy_from_slice(&g[i * n..(i + 1) * n]);
                        }
                        next.copy_from_slice(&out_c[li * n..(li + 1) * n]);
                    }
                });
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_seq(n: usize, len: usize, seed: u64) -> (Vec<f64>, Vec<f64>, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let mut a = vec![0.0; len * n * n];
        let mut b = vec![0.0; len * n];
        let mut y0 = vec![0.0; n];
        rng.fill_normal(&mut a, 0.4);
        rng.fill_normal(&mut b, 1.0);
        rng.fill_normal(&mut y0, 1.0);
        (a, b, y0)
    }

    #[test]
    fn par_matches_seq_forward() {
        for &(n, len, threads) in &[(1usize, 100usize, 4usize), (2, 257, 3), (4, 64, 8), (3, 1000, 2)] {
            let (a, b, y0) = random_seq(n, len, n as u64 * 31 + len as u64);
            let mut out_s = vec![0.0; len * n];
            let mut out_p = vec![0.0; len * n];
            seq_scan_apply(&a, &b, &y0, &mut out_s, n, len);
            par_scan_apply(&a, &b, &y0, &mut out_p, n, len, threads);
            for (i, (x, y)) in out_s.iter().zip(out_p.iter()).enumerate() {
                assert!((x - y).abs() < 1e-9, "n={n} len={len} t={threads} i={i}: {x} vs {y}");
            }
        }
    }

    #[test]
    fn par_matches_seq_reverse() {
        for &(n, len, threads) in &[(1usize, 97usize, 4usize), (2, 300, 3), (4, 65, 8)] {
            let (a, g, _) = random_seq(n, len, n as u64 * 17 + len as u64);
            let mut out_s = vec![0.0; len * n];
            let mut out_p = vec![0.0; len * n];
            seq_scan_reverse(&a, &g, &mut out_s, n, len);
            par_scan_reverse(&a, &g, &mut out_p, n, len, threads);
            for (i, (x, y)) in out_s.iter().zip(out_p.iter()).enumerate() {
                assert!((x - y).abs() < 1e-9, "n={n} len={len} t={threads} i={i}: {x} vs {y}");
            }
        }
    }

    #[test]
    fn short_sequences_fall_back() {
        let (a, b, y0) = random_seq(2, 5, 9);
        let mut out_s = vec![0.0; 10];
        let mut out_p = vec![0.0; 10];
        seq_scan_apply(&a, &b, &y0, &mut out_s, 2, 5);
        par_scan_apply(&a, &b, &y0, &mut out_p, 2, 5, 8);
        assert_eq!(out_s, out_p);
    }

    #[test]
    fn uneven_chunk_lengths() {
        // len not divisible by threads exercises the tail chunk.
        let (a, b, y0) = random_seq(3, 101, 10);
        let mut out_s = vec![0.0; 303];
        let mut out_p = vec![0.0; 303];
        seq_scan_apply(&a, &b, &y0, &mut out_s, 3, 101);
        par_scan_apply(&a, &b, &y0, &mut out_p, 3, 101, 7);
        for (x, y) in out_s.iter().zip(out_p.iter()) {
            assert!((x - y).abs() < 1e-9);
        }
    }

    /// One fused batched call must equal B independent sequential scans,
    /// for every scheduling regime (B ≥ threads, B < threads, threads ≤ 1).
    #[test]
    fn batch_forward_matches_per_sequence() {
        for &(n, t_len, batch, threads) in
            &[(3usize, 120usize, 5usize, 2usize), (2, 300, 2, 8), (4, 64, 3, 1), (1, 200, 8, 4)]
        {
            let mut rng = Rng::new(500 + (n * batch * threads) as u64);
            let mut a = vec![0.0f64; batch * t_len * n * n];
            let mut b = vec![0.0f64; batch * t_len * n];
            let mut y0s = vec![0.0f64; batch * n];
            rng.fill_normal(&mut a, 0.4);
            rng.fill_normal(&mut b, 1.0);
            rng.fill_normal(&mut y0s, 1.0);

            let mut want = vec![0.0f64; batch * t_len * n];
            for s in 0..batch {
                seq_scan_apply(
                    &a[s * t_len * n * n..(s + 1) * t_len * n * n],
                    &b[s * t_len * n..(s + 1) * t_len * n],
                    &y0s[s * n..(s + 1) * n],
                    &mut want[s * t_len * n..(s + 1) * t_len * n],
                    n,
                    t_len,
                );
            }
            let mut got = vec![0.0f64; batch * t_len * n];
            let mut ws = ScanWorkspace::new();
            par_scan_apply_batch_ws(
                &a, &b, &y0s, &mut got, n, t_len, batch, None, threads, &mut ws,
            );
            for (i, (x, y)) in want.iter().zip(got.iter()).enumerate() {
                assert!(
                    (x - y).abs() < 1e-9,
                    "n={n} T={t_len} B={batch} thr={threads} i={i}: {x} vs {y}"
                );
            }
        }
    }

    #[test]
    fn batch_reverse_matches_per_sequence() {
        for &(n, t_len, batch, threads) in
            &[(3usize, 90usize, 4usize, 2usize), (2, 257, 2, 6), (4, 70, 5, 1)]
        {
            let mut rng = Rng::new(700 + (n * batch * threads) as u64);
            let mut a = vec![0.0f64; batch * t_len * n * n];
            let mut g = vec![0.0f64; batch * t_len * n];
            rng.fill_normal(&mut a, 0.4);
            rng.fill_normal(&mut g, 1.0);

            let mut want = vec![0.0f64; batch * t_len * n];
            for s in 0..batch {
                seq_scan_reverse(
                    &a[s * t_len * n * n..(s + 1) * t_len * n * n],
                    &g[s * t_len * n..(s + 1) * t_len * n],
                    &mut want[s * t_len * n..(s + 1) * t_len * n],
                    n,
                    t_len,
                );
            }
            let mut got = vec![0.0f64; batch * t_len * n];
            let mut ws = ScanWorkspace::new();
            par_scan_reverse_batch_ws(&a, &g, &mut got, n, t_len, batch, None, threads, &mut ws);
            for (x, y) in want.iter().zip(got.iter()) {
                assert!((x - y).abs() < 1e-9, "B={batch} thr={threads}: {x} vs {y}");
            }
        }
    }

    /// Masked-out sequences must be left untouched (the convergence-freeze
    /// contract) while active ones still compute correctly.
    #[test]
    fn batch_mask_freezes_inactive_sequences() {
        let (n, t_len, batch) = (2usize, 80usize, 4usize);
        let mut rng = Rng::new(901);
        let mut a = vec![0.0f64; batch * t_len * n * n];
        let mut b = vec![0.0f64; batch * t_len * n];
        let mut y0s = vec![0.0f64; batch * n];
        rng.fill_normal(&mut a, 0.4);
        rng.fill_normal(&mut b, 1.0);
        rng.fill_normal(&mut y0s, 1.0);

        let sentinel = -777.0f64;
        for threads in [1usize, 3] {
            let mut got = vec![sentinel; batch * t_len * n];
            let active = [true, false, true, false];
            let mut ws = ScanWorkspace::new();
            par_scan_apply_batch_ws(
                &a, &b, &y0s, &mut got, n, t_len, batch, Some(&active), threads, &mut ws,
            );
            for s in 0..batch {
                let slab = &got[s * t_len * n..(s + 1) * t_len * n];
                if active[s] {
                    let mut want = vec![0.0f64; t_len * n];
                    seq_scan_apply(
                        &a[s * t_len * n * n..(s + 1) * t_len * n * n],
                        &b[s * t_len * n..(s + 1) * t_len * n],
                        &y0s[s * n..(s + 1) * n],
                        &mut want,
                        n,
                        t_len,
                    );
                    for (x, y) in want.iter().zip(slab.iter()) {
                        assert!((x - y).abs() < 1e-9);
                    }
                } else {
                    assert!(slab.iter().all(|&v| v == sentinel), "masked seq {s} written");
                }
            }
        }
    }

    /// A workspace reused across calls (different shapes) must not change
    /// results — the buffers only ever grow and are fully overwritten.
    #[test]
    fn workspace_reuse_is_sound() {
        let mut ws = ScanWorkspace::new();
        for &(n, len, threads) in &[(4usize, 200usize, 4usize), (2, 64, 8), (5, 333, 3)] {
            let (a, b, y0) = random_seq(n, len, 1000 + n as u64);
            let mut out_s = vec![0.0; len * n];
            let mut out_p = vec![0.0; len * n];
            seq_scan_apply(&a, &b, &y0, &mut out_s, n, len);
            par_scan_apply_ws(&a, &b, &y0, &mut out_p, n, len, threads, &mut ws);
            for (x, y) in out_s.iter().zip(out_p.iter()) {
                assert!((x - y).abs() < 1e-9);
            }
            let mut rev_s = vec![0.0; len * n];
            let mut rev_p = vec![0.0; len * n];
            seq_scan_reverse(&a, &b, &mut rev_s, n, len);
            par_scan_reverse_ws(&a, &b, &mut rev_p, n, len, threads, &mut ws);
            for (x, y) in rev_s.iter().zip(rev_p.iter()) {
                assert!((x - y).abs() < 1e-9);
            }
        }
    }
}
