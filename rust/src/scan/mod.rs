//! Prefix scans over affine recurrence elements — the paper's eq. (10).
//!
//! The inverse linear operator `L_G⁻¹` of both DEER-RNN (eq. 11) and
//! DEER-ODE (eq. 9) reduces to the first-order affine recurrence
//!
//! ```text
//! y_i = A_i · y_{i−1} + b_i ,          i = 1 … L
//! ```
//!
//! with the associative combine `(A₂,b₂) • (A₁,b₁) = (A₂A₁, A₂b₁ + b₂)`.
//!
//! * [`seq`] — the O(n²) -per-step sequential evaluation (also the baseline's
//!   inner loop).
//! * [`par`] — the parallel chunked three-phase scan (work O(n³·L/T) per
//!   worker, depth O(L/T + T)); on real accelerators this is
//!   `jax.lax.associative_scan`, reproduced at L1 by the Pallas kernel in
//!   `python/compile/kernels/assoc_scan.py` with the identical phase
//!   structure.
//! * reverse variants (`*_scan_reverse`) — the dual (transposed) scan used by the DEER backward pass
//!   (paper eq. 7): `λ_i = g_i + A_{i+1}ᵀ λ_{i+1}`.

pub mod par;
pub mod seq;

pub use par::{par_scan_apply, par_scan_reverse};
pub use seq::{seq_scan_apply, seq_scan_reverse};

use crate::util::scalar::Scalar;

/// Packed affine elements: `a` holds `len` row-major n×n matrices, `b` holds
/// `len` n-vectors.
#[derive(Debug, Clone)]
pub struct AffineSeq<S> {
    pub n: usize,
    pub len: usize,
    pub a: Vec<S>,
    pub b: Vec<S>,
}

impl<S: Scalar> AffineSeq<S> {
    pub fn zeros(n: usize, len: usize) -> Self {
        AffineSeq {
            n,
            len,
            a: vec![S::zero(); len * n * n],
            b: vec![S::zero(); len * n],
        }
    }

    #[inline]
    pub fn a_at(&self, i: usize) -> &[S] {
        &self.a[i * self.n * self.n..(i + 1) * self.n * self.n]
    }
    #[inline]
    pub fn b_at(&self, i: usize) -> &[S] {
        &self.b[i * self.n..(i + 1) * self.n]
    }
    #[inline]
    pub fn a_at_mut(&mut self, i: usize) -> &mut [S] {
        &mut self.a[i * self.n * self.n..(i + 1) * self.n * self.n]
    }
    #[inline]
    pub fn b_at_mut(&mut self, i: usize) -> &mut [S] {
        &mut self.b[i * self.n..(i + 1) * self.n]
    }
}

/// The associative operator of eq. (10):
/// `out = later ∘ earlier`, i.e. `(A_l A_e, A_l b_e + b_l)`.
#[inline]
pub fn combine<S: Scalar>(
    a_later: &[S],
    b_later: &[S],
    a_earlier: &[S],
    b_earlier: &[S],
    a_out: &mut [S],
    b_out: &mut [S],
    n: usize,
) {
    crate::linalg::matmul(a_later, a_earlier, a_out, n);
    crate::linalg::matvec(a_later, b_earlier, b_out);
    for i in 0..n {
        b_out[i] += b_later[i];
    }
}

/// FLOPs for applying the recurrence once per element (matvec + add).
pub fn flops_apply(n: usize, len: usize) -> u64 {
    (2 * n * n + n) as u64 * len as u64
}

/// FLOPs for composing two elements (matmul + matvec + add).
pub fn flops_combine(n: usize) -> u64 {
    (2 * n * n * n + 2 * n * n + n) as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// combine must be associative: (c•b)•a == c•(b•a).
    #[test]
    fn combine_is_associative() {
        let n = 3;
        let mut rng = Rng::new(77);
        let mut el = Vec::new();
        for _ in 0..3 {
            let mut a = vec![0.0f64; n * n];
            let mut b = vec![0.0f64; n];
            rng.fill_normal(&mut a, 1.0);
            rng.fill_normal(&mut b, 1.0);
            el.push((a, b));
        }
        let (a0, b0) = &el[0];
        let (a1, b1) = &el[1];
        let (a2, b2) = &el[2];

        let mut t_a = vec![0.0; n * n];
        let mut t_b = vec![0.0; n];
        let mut l_a = vec![0.0; n * n];
        let mut l_b = vec![0.0; n];
        // left-assoc: (e2 • e1) • e0
        combine(a2, b2, a1, b1, &mut t_a, &mut t_b, n);
        combine(&t_a, &t_b, a0, b0, &mut l_a, &mut l_b, n);
        // right-assoc: e2 • (e1 • e0)
        let mut u_a = vec![0.0; n * n];
        let mut u_b = vec![0.0; n];
        let mut r_a = vec![0.0; n * n];
        let mut r_b = vec![0.0; n];
        combine(a1, b1, a0, b0, &mut u_a, &mut u_b, n);
        combine(a2, b2, &u_a, &u_b, &mut r_a, &mut r_b, n);

        for (x, y) in l_a.iter().zip(r_a.iter()) {
            assert!((x - y).abs() < 1e-12);
        }
        for (x, y) in l_b.iter().zip(r_b.iter()) {
            assert!((x - y).abs() < 1e-12);
        }
    }

    #[test]
    fn identity_element() {
        // (I, 0) is the identity of the monoid.
        let n = 2;
        let id_a = vec![1.0f64, 0.0, 0.0, 1.0];
        let id_b = vec![0.0; 2];
        let a = vec![0.5, -1.0, 2.0, 0.25];
        let b = vec![3.0, -4.0];
        let mut oa = vec![0.0; 4];
        let mut ob = vec![0.0; 2];
        combine(&a, &b, &id_a, &id_b, &mut oa, &mut ob, n);
        assert_eq!(oa, a);
        assert_eq!(ob, b);
        combine(&id_a, &id_b, &a, &b, &mut oa, &mut ob, n);
        assert_eq!(oa, a);
        assert_eq!(ob, b);
    }
}
