//! Prefix scans over affine recurrence elements — the paper's eq. (10).
//!
//! The inverse linear operator `L_G⁻¹` of both DEER-RNN (eq. 11) and
//! DEER-ODE (eq. 9) reduces to the first-order affine recurrence
//!
//! ```text
//! y_i = A_i · y_{i−1} + b_i ,          i = 1 … L
//! ```
//!
//! with the associative combine `(A₂,b₂) • (A₁,b₁) = (A₂A₁, A₂b₁ + b₂)`.
//!
//! # Structure dispatch
//!
//! The kernels come in two flavors keyed on [`JacobianStructure`]
//! (re-exported from [`crate::cells`]):
//!
//! * **Dense** — `A_i` is a full row-major n×n matrix. Compose costs
//!   O(n³) per element, apply O(n²). This is the general path and the
//!   paper's §3.5 cost model.
//! * **Diagonal** — `A_i` is packed as its n diagonal entries. Compose and
//!   apply are both O(n) elementwise ops, which removes the O(n³) compose
//!   wall flagged in §3.1.1 (the quasi-DEER / ParaRNN observation: with
//!   diagonal or diagonally-approximated Jacobians the whole INVLIN phase
//!   is linear in the state dimension). No n×n temporaries exist anywhere
//!   on this path.
//! * **Block(k)** — `A_i` is block-diagonal, packed as `[n/k, k, k]`
//!   contiguous k×k tiles (`n·k` elements per step). Compose costs
//!   O((n/k)·k³) = O(n·k²) per element, apply O(n·k): for k = 2 (the
//!   LSTM/LEM unit pairing) this is within 4× of the diagonal path's work
//!   while keeping the per-unit state coupling the diagonal approximation
//!   drops. The block monoid is closed, so the whole scan stays packed —
//!   O(T·n·k) memory, never O(T·n²).
//!
//! # Vectorization and the scalar-reference contract
//!
//! The compose kernels ([`combine`], [`combine_diag`], [`combine_block`])
//! are the INVLIN inner loop and run through the portable SIMD layer in
//! [`simd`]: fixed-width lane blocks ([`simd::LANE_BLOCK`] = 8) with scalar
//! tails for n not a lane multiple. Their original scalar loops survive as
//! [`combine_scalar`] / [`combine_diag_scalar`] / [`combine_block_scalar`]
//! — the **bitwise reference**: the vectorized kernels compute every output
//! element with the same expression in the same association order (no FMA,
//! no reduction reordering; the Block(2) tile multiply vectorizes *across*
//! units, never within a tile), and tests pin `assert_eq!` equality at
//! awkward shapes. See the [`simd`] module docs for the lane layout.
//!
//! # Schedule selection: chunked two-pass vs cyclic reduction
//!
//! Two parallel schedules exist for the intra-sequence scans:
//!
//! * **Chunked three-phase** ([`par`] and siblings) — work-efficient
//!   (compose ≈ 2× element work), depth O(L/threads + threads). Selected
//!   whenever chunks amortize: `len ≥ PAR_CROSSOVER_STEPS_PER_THREAD ×
//!   threads` (the centralized crossover every kernel and the simulator
//!   consult — see [`PAR_CROSSOVER_STEPS_PER_THREAD`]).
//! * **Cyclic reduction** ([`cr`]) — a Hillis–Steele log-depth sweep:
//!   O(L·log L / threads) work but only ⌈log₂ L⌉ levels of depth. In the
//!   short-sequence region (`len < crossover × threads`) the chunked
//!   schedule starves workers and used to fall back to sequential;
//!   [`choose_scan_schedule`] now compares the modeled critical paths of
//!   the sequential and cyclic-reduction schedules there (and the
//!   simulator uses the same chooser, so dispatch and cost model cannot
//!   disagree). CR wins when threads ≈ L and the per-element combine is
//!   cheap (diagonal / Block(2)); dense combine keeps sequential until the
//!   lane count exceeds ~n·log₂L, which matches the paper's §3.5 analysis.
//!
//! Modules:
//!
//! * [`seq`] — sequential evaluation (also the baseline's inner loop).
//! * [`par`] — parallel chunked three-phase dense scan (work O(n³·L/T) per
//!   worker, depth O(L/T + T)); on real accelerators this is
//!   `jax.lax.associative_scan`, reproduced at L1 by the Pallas kernel in
//!   `python/compile/kernels/assoc_scan.py` with the identical phase
//!   structure.
//! * [`cr`] — the O(log L)-depth cyclic-reduction variants
//!   (`par_*_scan_*_cr_ws`) for all four element families.
//! * [`simd`] — the portable lane types and vectorized compose kernels.
//! * [`diag`] — the O(n)-per-element diagonal kernels (seq + par, forward
//!   + reverse), used by natively-diagonal cells and by quasi-DEER mode.
//! * [`block`] — the packed block-diagonal kernels (seq + par, forward +
//!   reverse, batched with the active mask), used by the `Block(k)` path:
//!   natively-block cells and the `BlockApprox` quasi mode. On a dense
//!   embedding of the same blocks they reproduce the dense kernels
//!   bitwise, so Block-vs-Dense dispatch never changes results.
//! * reverse variants (`*_scan_reverse`) — the dual (transposed) scan used
//!   by the DEER backward pass (paper eq. 7): `λ_i = g_i + A_{i+1}ᵀ λ_{i+1}`.
//!   For diagonal `A`, transpose is a no-op; for block `A` it transposes
//!   each k×k tile in place.
//!
//! All parallel kernels take an optional reusable [`ScanWorkspace`] (the
//! `*_ws` entry points) so the Newton hot loop performs no per-iteration
//! scratch allocation.
//!
//! # Batched `[B, T, n…]` layout
//!
//! Every kernel has a fused batched variant (`*_batch` / `*_batch_ws`)
//! operating on B independent sequences packed sequence-major:
//! `a = [B, T, jac]`, `b = [B, T, n]`, `y0s = [B, n]`, `out = [B, T, n]`
//! (sequence `s` owns the contiguous slab `s·T·len .. (s+1)·T·len`). The
//! recurrences never cross sequence boundaries — the batch axis is
//! embarrassingly parallel — so one call schedules the whole B×T element
//! grid over the thread pool instead of paying per-sequence dispatch:
//!
//! * **B ≥ threads** (the common serving shape): workers take whole
//!   sequences round-robin and run the plain *sequential* kernel on each.
//!   Cross-sequence parallelism does zero redundant work — unlike the
//!   intra-sequence three-phase scan, whose compose phase re-does the
//!   apply-phase multiplies (~2–3× element work) — and the per-call spawn/
//!   join cost is paid once per batch rather than once per sequence.
//! * **B < threads**: the leftover workers split inside sequences — each
//!   sequence runs its own three-phase chunked scan with
//!   `threads / B_active` lanes.
//!
//! # Convergence masking
//!
//! The batched entry points accept an optional `active: &[bool]` mask
//! (length B). Masked-out sequences are never read or written — the DEER
//! driver uses this to freeze converged sequences in place while stragglers
//! keep iterating, so a batch costs `Σ_b iters_b`, not `B · max_b iters_b`,
//! element updates (see `crate::deer::newton::deer_rnn_batch`).

pub mod block;
pub mod cr;
pub mod diag;
pub mod kalman;
pub mod par;
pub mod seq;
pub mod simd;

pub use kalman::{
    damp_gain, par_kalman_scan_apply_batch_ws, par_kalman_scan_apply_ws,
    par_kalman_scan_reverse_batch_ws, par_kalman_scan_reverse_ws, seq_kalman_scan_apply,
    seq_kalman_scan_reverse,
};

pub use block::{
    par_block_scan_apply, par_block_scan_apply_batch_ws, par_block_scan_apply_ws,
    par_block_scan_reverse, par_block_scan_reverse_batch_ws, par_block_scan_reverse_ws,
    seq_block_scan_apply, seq_block_scan_reverse,
};
pub use diag::{
    par_diag_scan_apply, par_diag_scan_apply_ws, par_diag_scan_apply_batch_ws,
    par_diag_scan_reverse, par_diag_scan_reverse_ws, par_diag_scan_reverse_batch_ws,
    seq_diag_scan_apply, seq_diag_scan_reverse,
};
pub use par::{
    par_scan_apply, par_scan_apply_ws, par_scan_apply_batch_ws, par_scan_reverse,
    par_scan_reverse_ws, par_scan_reverse_batch_ws,
};
pub use cr::{
    par_block_scan_apply_cr_ws, par_block_scan_reverse_cr_ws, par_diag_scan_apply_cr_ws,
    par_diag_scan_reverse_cr_ws, par_kalman_scan_apply_cr_ws, par_kalman_scan_reverse_cr_ws,
    par_scan_apply_cr_ws, par_scan_reverse_cr_ws,
};
pub use seq::{seq_scan_apply, seq_scan_reverse};

use crate::util::scalar::Scalar;

/// The centralized short-sequence crossover: the chunked three-phase scans
/// need at least this many steps **per thread** to amortize their compose
/// phase (~2× element work) and two barriers. Below it the parallel kernels
/// either run sequentially or — when [`choose_scan_schedule`] says the
/// log-depth sweep wins — via cyclic reduction. Both the `par_*_ws` kernels
/// and the simulator cost model consult this one constant, so runtime
/// fallback and modeled dispatch cannot disagree.
pub const PAR_CROSSOVER_STEPS_PER_THREAD: usize = 4;

/// Modeled cost of one barrier / level synchronization, in flop units —
/// the same "thread count models accelerator lanes" convention the rest of
/// the crate uses (spawn cost on this CPU testbed is *not* what's modeled;
/// see [`crate::simulator`]). Chosen so cyclic reduction is only selected
/// where its log-depth genuinely pays: cheap combines (diagonal, Block(2))
/// at thread counts near the sequence length.
pub const SYNC_FLOPS: u64 = 64;

/// Which schedule a parallel scan should run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScanSchedule {
    /// One worker replays the recurrence; depth = len.
    Sequential,
    /// Three-phase chunked scan; depth ≈ len/threads + threads.
    Chunked,
    /// Hillis–Steele cyclic reduction; depth = ⌈log₂ len⌉ levels.
    CyclicReduction,
}

impl ScanSchedule {
    /// Stable lowercase label for logs / JSON / trace events.
    pub fn label(&self) -> &'static str {
        match self {
            ScanSchedule::Sequential => "sequential",
            ScanSchedule::Chunked => "chunked",
            ScanSchedule::CyclicReduction => "cyclic_reduction",
        }
    }

    /// The always-on telemetry counter tracking how often this schedule is
    /// dispatched at runtime.
    pub fn counter(&self) -> crate::telemetry::Counter {
        match self {
            ScanSchedule::Sequential => crate::telemetry::Counter::ScanSequential,
            ScanSchedule::Chunked => crate::telemetry::Counter::ScanChunked,
            ScanSchedule::CyclicReduction => crate::telemetry::Counter::ScanCyclicReduction,
        }
    }
}

/// Pick the scan schedule for a `len`-element scan on `threads` workers,
/// given the per-element compose and apply costs in flops (use the
/// `flops_combine*` / `flops_apply*(…, 1)` helpers for the structure at
/// hand). The rule:
///
/// 1. `threads ≤ 1` (or a degenerate scan) → [`ScanSchedule::Sequential`].
/// 2. `len ≥ PAR_CROSSOVER_STEPS_PER_THREAD × threads` →
///    [`ScanSchedule::Chunked`] (chunks amortize; the work-efficient
///    schedule wins on throughput).
/// 3. Otherwise the chunked schedule starves workers. Compare modeled
///    critical paths: sequential = `len·apply`; cyclic reduction =
///    `⌈log₂len⌉·(⌈len/threads⌉·combine + sync) + ⌈len/threads⌉·apply +
///    sync`. Return whichever is cheaper.
///
/// The same function drives both the runtime kernels' fallback and the
/// simulator's INVLIN depth term.
pub fn choose_scan_schedule(
    len: usize,
    threads: usize,
    combine_flops: u64,
    apply_flops: u64,
) -> ScanSchedule {
    if threads <= 1 || len <= 2 {
        return ScanSchedule::Sequential;
    }
    if len >= PAR_CROSSOVER_STEPS_PER_THREAD * threads {
        return ScanSchedule::Chunked;
    }
    let levels = (usize::BITS - (len - 1).leading_zeros()) as u64; // ⌈log₂ len⌉
    let per = len.div_ceil(threads) as u64;
    let cr_cost = levels * (per * combine_flops + SYNC_FLOPS) + per * apply_flops + SYNC_FLOPS;
    let seq_cost = len as u64 * apply_flops;
    if cr_cost < seq_cost {
        ScanSchedule::CyclicReduction
    } else {
        ScanSchedule::Sequential
    }
}

/// [`choose_scan_schedule`] plus observability: bumps the per-schedule
/// dispatch counter and the scan-length histogram (always on, relaxed
/// atomics), and — only when the telemetry sink is enabled — emits a
/// `scan_schedule` trace instant carrying the inputs the decision was made
/// with. The decision itself is bitwise the same as the silent chooser.
///
/// Runtime dispatch sites call THIS wrapper; the simulator keeps calling
/// the silent [`choose_scan_schedule`] so modeling a schedule never pollutes
/// the observed-dispatch counters.
pub fn choose_scan_schedule_observed(
    len: usize,
    threads: usize,
    combine_flops: u64,
    apply_flops: u64,
) -> ScanSchedule {
    let schedule = choose_scan_schedule(len, threads, combine_flops, apply_flops);
    crate::telemetry::counter_add(schedule.counter(), 1);
    crate::telemetry::histogram_record(crate::telemetry::Histogram::ScanLen, len as u64);
    if crate::telemetry::enabled() {
        use crate::telemetry::ArgValue;
        crate::telemetry::instant(
            "scan_schedule",
            vec![
                ("schedule", ArgValue::Str(schedule.label())),
                ("len", ArgValue::Num(len as f64)),
                ("threads", ArgValue::Num(threads as f64)),
                ("combine_flops", ArgValue::Num(combine_flops as f64)),
                ("apply_flops", ArgValue::Num(apply_flops as f64)),
            ],
        );
    }
    schedule
}

/// Indices of the sequences a batched kernel should touch: every sequence,
/// or only those flagged in an `active` mask (the convergence-masking hook).
pub(crate) fn active_indices(batch: usize, active: Option<&[bool]>) -> Vec<usize> {
    match active {
        None => (0..batch).collect(),
        Some(mask) => {
            debug_assert_eq!(mask.len(), batch, "active mask length");
            (0..batch).filter(|&s| mask[s]).collect()
        }
    }
}

/// Decompose the active part of the `[B, T]` element grid into per-sequence
/// contiguous time ranges `(seq, lo, hi)` so ~`threads` workers stay busy:
/// each active sequence gets `max(1, threads / batch)` chunks (1 when the
/// sequence is too short to amortize chunking). Chunks never span sequences
/// — the scan monoid does not compose across the batch axis.
///
/// The chunks-per-sequence divisor is the **total** batch size, not the
/// active count: the decomposition (hence floating-point accumulation
/// order) of a sequence must stay identical across Newton sweeps even as
/// its neighbours freeze, so batched results are bit-reproducible and
/// independent of masking state.
pub(crate) fn plan_batch_chunks(
    t_len: usize,
    active_seqs: &[usize],
    threads: usize,
    batch: usize,
) -> Vec<(usize, usize, usize)> {
    let n_active = active_seqs.len();
    if n_active == 0 || t_len == 0 {
        return Vec::new();
    }
    let mut cps = if threads <= 1 { 1 } else { (threads / batch.max(1)).max(1) };
    if t_len < PAR_CROSSOVER_STEPS_PER_THREAD * cps {
        cps = 1;
    }
    let chunk_len = t_len.div_ceil(cps);
    let mut out = Vec::with_capacity(n_active * cps);
    for &s in active_seqs {
        for c in 0..cps {
            let lo = (c * chunk_len).min(t_len);
            let hi = ((c + 1) * chunk_len).min(t_len);
            if lo < hi {
                out.push((s, lo, hi));
            }
        }
    }
    out
}

/// Reusable scratch buffers for the chunked parallel scans.
///
/// The three-phase scan needs per-chunk composed elements (`comp_a`,
/// `comp_b`) and per-chunk carry states (`carry`). Allocating them inside
/// every call put three `Vec` allocations on every Newton iteration; the
/// DEER driver now owns one workspace per evaluation and threads it through
/// ([`par::par_scan_apply_ws`] and friends). Buffers only grow.
#[derive(Debug, Default)]
pub struct ScanWorkspace<S> {
    pub(crate) comp_a: Vec<S>,
    pub(crate) comp_b: Vec<S>,
    pub(crate) carry: Vec<S>,
}

impl<S: Scalar> ScanWorkspace<S> {
    pub fn new() -> Self {
        ScanWorkspace {
            comp_a: Vec::new(),
            comp_b: Vec::new(),
            carry: Vec::new(),
        }
    }

    /// Grow (never shrink) the three buffers to the requested lengths.
    pub(crate) fn ensure(&mut self, a_len: usize, b_len: usize, carry_len: usize) {
        if self.comp_a.len() < a_len {
            self.comp_a.resize(a_len, S::zero());
        }
        if self.comp_b.len() < b_len {
            self.comp_b.resize(b_len, S::zero());
        }
        if self.carry.len() < carry_len {
            self.carry.resize(carry_len, S::zero());
        }
    }
}

/// Packed affine elements: `a` holds `len` row-major n×n matrices, `b` holds
/// `len` n-vectors.
#[derive(Debug, Clone)]
pub struct AffineSeq<S> {
    pub n: usize,
    pub len: usize,
    pub a: Vec<S>,
    pub b: Vec<S>,
}

impl<S: Scalar> AffineSeq<S> {
    pub fn zeros(n: usize, len: usize) -> Self {
        AffineSeq {
            n,
            len,
            a: vec![S::zero(); len * n * n],
            b: vec![S::zero(); len * n],
        }
    }

    #[inline]
    pub fn a_at(&self, i: usize) -> &[S] {
        &self.a[i * self.n * self.n..(i + 1) * self.n * self.n]
    }
    #[inline]
    pub fn b_at(&self, i: usize) -> &[S] {
        &self.b[i * self.n..(i + 1) * self.n]
    }
    #[inline]
    pub fn a_at_mut(&mut self, i: usize) -> &mut [S] {
        &mut self.a[i * self.n * self.n..(i + 1) * self.n * self.n]
    }
    #[inline]
    pub fn b_at_mut(&mut self, i: usize) -> &mut [S] {
        &mut self.b[i * self.n..(i + 1) * self.n]
    }
}

/// The associative operator of eq. (10):
/// `out = later ∘ earlier`, i.e. `(A_l A_e, A_l b_e + b_l)`.
///
/// The matmul runs cache-blocked with lane-vectorized axpy rows
/// ([`simd::matmul_blocked`]); [`combine_scalar`] is the bitwise reference.
#[inline]
pub fn combine<S: Scalar>(
    a_later: &[S],
    b_later: &[S],
    a_earlier: &[S],
    b_earlier: &[S],
    a_out: &mut [S],
    b_out: &mut [S],
    n: usize,
) {
    simd::matmul_blocked(a_later, a_earlier, a_out, n);
    crate::linalg::matvec(a_later, b_earlier, b_out);
    for i in 0..n {
        b_out[i] += b_later[i];
    }
}

/// Scalar reference for [`combine`] — the original unblocked loops. The
/// vectorized kernel must match it bitwise (pinned by tests).
#[inline]
pub fn combine_scalar<S: Scalar>(
    a_later: &[S],
    b_later: &[S],
    a_earlier: &[S],
    b_earlier: &[S],
    a_out: &mut [S],
    b_out: &mut [S],
    n: usize,
) {
    crate::linalg::matmul(a_later, a_earlier, a_out, n);
    crate::linalg::matvec(a_later, b_earlier, b_out);
    for i in 0..n {
        b_out[i] += b_later[i];
    }
}

/// Diagonal specialization of the eq. (10) combine: with `A = diag(a)` the
/// operator degenerates to `(a_l ⊙ a_e, a_l ⊙ b_e + b_l)` — O(n), and the
/// diagonal monoid is closed so the whole scan stays packed.
///
/// Runs through the portable SIMD lanes ([`simd::combine_diag_lanes`]);
/// [`combine_diag_scalar`] is the bitwise reference.
#[inline]
pub fn combine_diag<S: Scalar>(
    a_later: &[S],
    b_later: &[S],
    a_earlier: &[S],
    b_earlier: &[S],
    a_out: &mut [S],
    b_out: &mut [S],
    n: usize,
) {
    simd::combine_diag_lanes(a_later, b_later, a_earlier, b_earlier, a_out, b_out, n);
}

/// Scalar reference for [`combine_diag`] — the original elementwise loop
/// (whose six independently-indexed slices keep per-element bounds checks
/// and therefore never autovectorized). The lane kernel must match it
/// bitwise (pinned by tests).
#[inline]
pub fn combine_diag_scalar<S: Scalar>(
    a_later: &[S],
    b_later: &[S],
    a_earlier: &[S],
    b_earlier: &[S],
    a_out: &mut [S],
    b_out: &mut [S],
    n: usize,
) {
    for i in 0..n {
        a_out[i] = a_later[i] * a_earlier[i];
        b_out[i] = a_later[i] * b_earlier[i] + b_later[i];
    }
}

/// FLOPs for applying the dense recurrence once per element (matvec + add).
pub fn flops_apply(n: usize, len: usize) -> u64 {
    (2 * n * n + n) as u64 * len as u64
}

/// FLOPs for composing two dense elements (matmul + matvec + add).
pub fn flops_combine(n: usize) -> u64 {
    (2 * n * n * n + 2 * n * n + n) as u64
}

/// FLOPs for applying the diagonal recurrence once per element (⊙ + add).
pub fn flops_apply_diag(n: usize, len: usize) -> u64 {
    (2 * n) as u64 * len as u64
}

/// FLOPs for composing two diagonal elements — O(n), the crux of the
/// structured fast path (vs. O(n³) dense).
pub fn flops_combine_diag(n: usize) -> u64 {
    (3 * n) as u64
}

/// Block-diagonal specialization of the eq. (10) combine: n/k independent
/// k×k tile products — `(A_l^{(b)} A_e^{(b)}, A_l^{(b)} b_e^{(b)} + b_l^{(b)})`
/// per block. O(n·k²), the `Block(k)` middle rung between diagonal O(n)
/// and dense O(n³).
///
/// The k = 2 case (LSTM/LEM unit pairing — the hot one) vectorizes across
/// units through [`simd::combine_block2_lanes`]; other k run the scalar
/// tile loops. [`combine_block_scalar`] is the bitwise reference.
#[allow(clippy::too_many_arguments)]
pub fn combine_block<S: Scalar>(
    a_later: &[S],
    b_later: &[S],
    a_earlier: &[S],
    b_earlier: &[S],
    a_out: &mut [S],
    b_out: &mut [S],
    n: usize,
    k: usize,
) {
    if k == 2 {
        simd::combine_block2_lanes(a_later, b_later, a_earlier, b_earlier, a_out, b_out, n);
        return;
    }
    combine_block_scalar(a_later, b_later, a_earlier, b_earlier, a_out, b_out, n, k);
}

/// Scalar reference for [`combine_block`] — the original per-tile loops.
/// The vectorized k = 2 kernel must match it bitwise (pinned by tests).
#[allow(clippy::too_many_arguments)]
pub fn combine_block_scalar<S: Scalar>(
    a_later: &[S],
    b_later: &[S],
    a_earlier: &[S],
    b_earlier: &[S],
    a_out: &mut [S],
    b_out: &mut [S],
    n: usize,
    k: usize,
) {
    debug_assert_eq!(n % k, 0);
    let nb = n / k;
    for bb in 0..nb {
        let al = &a_later[bb * k * k..(bb + 1) * k * k];
        let ae = &a_earlier[bb * k * k..(bb + 1) * k * k];
        let ao = &mut a_out[bb * k * k..(bb + 1) * k * k];
        for v in ao.iter_mut() {
            *v = S::zero();
        }
        for r in 0..k {
            for kk in 0..k {
                let aik = al[r * k + kk];
                let brow = &ae[kk * k..(kk + 1) * k];
                let crow = &mut ao[r * k..(r + 1) * k];
                for c in 0..k {
                    crow[c] += aik * brow[c];
                }
            }
        }
        for r in 0..k {
            let row = &al[r * k..(r + 1) * k];
            let mut acc = S::zero();
            for c in 0..k {
                acc += row[c] * b_earlier[bb * k + c];
            }
            b_out[bb * k + r] = acc + b_later[bb * k + r];
        }
    }
}

/// FLOPs for applying the block recurrence once per element
/// (n/k k×k matvecs + add).
pub fn flops_apply_block(n: usize, k: usize, len: usize) -> u64 {
    ((2 * k + 1) * n) as u64 * len as u64
}

/// FLOPs for composing two block-diagonal elements — the O((n/k)·k³)
/// compose term of the `Block(k)` path: n/k tile matmuls + matvecs + adds.
pub fn flops_combine_block(n: usize, k: usize) -> u64 {
    ((n / k) as u64) * (2 * (k as u64).pow(3) + 2 * (k as u64).pow(2) + k as u64)
}

/// FLOPs for applying the damped (Kalman/information-filter) dense
/// recurrence once per element: the plain matvec + add plus the λ·z axpy
/// and the `s = 1/(1+λ)` gain (3n extra over [`flops_apply`]).
pub fn flops_apply_kalman(n: usize, len: usize) -> u64 {
    flops_apply(n, len) + (3 * n) as u64 * len as u64
}

/// FLOPs for composing two damped dense elements: the plain combine plus
/// scaling the later propagator (`n²`) and building `s·(b + λz)` (3n).
pub fn flops_combine_kalman(n: usize) -> u64 {
    flops_combine(n) + (n * n + 3 * n) as u64
}

/// Diagonal damped apply: plain ⊙ + add plus the λ·z axpy and the gain.
pub fn flops_apply_kalman_diag(n: usize, len: usize) -> u64 {
    flops_apply_diag(n, len) + (3 * n) as u64 * len as u64
}

/// Diagonal damped compose: plain compose plus scaled-element build.
pub fn flops_combine_kalman_diag(n: usize) -> u64 {
    flops_combine_diag(n) + (4 * n) as u64
}

/// Block damped apply: plain tile matvecs + add plus the λ·z axpy and gain.
pub fn flops_apply_kalman_block(n: usize, k: usize, len: usize) -> u64 {
    flops_apply_block(n, k, len) + (3 * n) as u64 * len as u64
}

/// Block damped compose: plain compose plus scaled-element build (n·k tile
/// scale + 3n rhs build).
pub fn flops_combine_kalman_block(n: usize, k: usize) -> u64 {
    flops_combine_block(n, k) + (n * k + 3 * n) as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// combine must be associative: (c•b)•a == c•(b•a).
    #[test]
    fn combine_is_associative() {
        let n = 3;
        let mut rng = Rng::new(77);
        let mut el = Vec::new();
        for _ in 0..3 {
            let mut a = vec![0.0f64; n * n];
            let mut b = vec![0.0f64; n];
            rng.fill_normal(&mut a, 1.0);
            rng.fill_normal(&mut b, 1.0);
            el.push((a, b));
        }
        let (a0, b0) = &el[0];
        let (a1, b1) = &el[1];
        let (a2, b2) = &el[2];

        let mut t_a = vec![0.0; n * n];
        let mut t_b = vec![0.0; n];
        let mut l_a = vec![0.0; n * n];
        let mut l_b = vec![0.0; n];
        // left-assoc: (e2 • e1) • e0
        combine(a2, b2, a1, b1, &mut t_a, &mut t_b, n);
        combine(&t_a, &t_b, a0, b0, &mut l_a, &mut l_b, n);
        // right-assoc: e2 • (e1 • e0)
        let mut u_a = vec![0.0; n * n];
        let mut u_b = vec![0.0; n];
        let mut r_a = vec![0.0; n * n];
        let mut r_b = vec![0.0; n];
        combine(a1, b1, a0, b0, &mut u_a, &mut u_b, n);
        combine(a2, b2, &u_a, &u_b, &mut r_a, &mut r_b, n);

        for (x, y) in l_a.iter().zip(r_a.iter()) {
            assert!((x - y).abs() < 1e-12);
        }
        for (x, y) in l_b.iter().zip(r_b.iter()) {
            assert!((x - y).abs() < 1e-12);
        }
    }

    #[test]
    fn identity_element() {
        // (I, 0) is the identity of the monoid.
        let n = 2;
        let id_a = vec![1.0f64, 0.0, 0.0, 1.0];
        let id_b = vec![0.0; 2];
        let a = vec![0.5, -1.0, 2.0, 0.25];
        let b = vec![3.0, -4.0];
        let mut oa = vec![0.0; 4];
        let mut ob = vec![0.0; 2];
        combine(&a, &b, &id_a, &id_b, &mut oa, &mut ob, n);
        assert_eq!(oa, a);
        assert_eq!(ob, b);
        combine(&id_a, &id_b, &a, &b, &mut oa, &mut ob, n);
        assert_eq!(oa, a);
        assert_eq!(ob, b);
    }

    /// combine_diag must agree with the dense combine on embedded diagonals.
    #[test]
    fn combine_diag_matches_dense_embedding() {
        let n = 4;
        let mut rng = Rng::new(99);
        let mut dl = vec![0.0f64; n];
        let mut de = vec![0.0f64; n];
        let mut bl = vec![0.0f64; n];
        let mut be = vec![0.0f64; n];
        rng.fill_normal(&mut dl, 1.0);
        rng.fill_normal(&mut de, 1.0);
        rng.fill_normal(&mut bl, 1.0);
        rng.fill_normal(&mut be, 1.0);

        // packed diagonal combine
        let mut oa = vec![0.0; n];
        let mut ob = vec![0.0; n];
        combine_diag(&dl, &bl, &de, &be, &mut oa, &mut ob, n);

        // dense combine on embedded matrices
        let embed = |d: &[f64]| {
            let mut m = vec![0.0; n * n];
            for i in 0..n {
                m[i * n + i] = d[i];
            }
            m
        };
        let (ml, me) = (embed(&dl), embed(&de));
        let mut da = vec![0.0; n * n];
        let mut db = vec![0.0; n];
        combine(&ml, &bl, &me, &be, &mut da, &mut db, n);
        for i in 0..n {
            assert!((oa[i] - da[i * n + i]).abs() < 1e-14);
            assert!((ob[i] - db[i]).abs() < 1e-14);
        }
    }

    #[test]
    fn diag_flops_are_linear() {
        assert_eq!(flops_combine_diag(16), 48);
        assert!(flops_combine(16) / flops_combine_diag(16) > 100);
        assert_eq!(flops_apply_diag(8, 10), 160);
    }

    /// combine_block must agree with the dense combine on embedded
    /// block-diagonal matrices (bitwise — the dispatch contract).
    #[test]
    fn combine_block_matches_dense_embedding() {
        let (n, k) = (6usize, 2usize);
        let mut rng = Rng::new(123);
        let mut al = vec![0.0f64; n * k];
        let mut ae = vec![0.0f64; n * k];
        let mut bl_ = vec![0.0f64; n];
        let mut be = vec![0.0f64; n];
        rng.fill_normal(&mut al, 1.0);
        rng.fill_normal(&mut ae, 1.0);
        rng.fill_normal(&mut bl_, 1.0);
        rng.fill_normal(&mut be, 1.0);

        let mut oa = vec![0.0; n * k];
        let mut ob = vec![0.0; n];
        combine_block(&al, &bl_, &ae, &be, &mut oa, &mut ob, n, k);

        let embed = |p: &[f64]| {
            let mut m = vec![0.0; n * n];
            for bb in 0..n / k {
                for r in 0..k {
                    for c in 0..k {
                        m[(bb * k + r) * n + bb * k + c] = p[bb * k * k + r * k + c];
                    }
                }
            }
            m
        };
        let (ml, me) = (embed(&al), embed(&ae));
        let mut da = vec![0.0; n * n];
        let mut db = vec![0.0; n];
        combine(&ml, &bl_, &me, &be, &mut da, &mut db, n);
        for bb in 0..n / k {
            for r in 0..k {
                for c in 0..k {
                    assert_eq!(
                        oa[bb * k * k + r * k + c],
                        da[(bb * k + r) * n + bb * k + c],
                        "block ({bb},{r},{c})"
                    );
                }
            }
        }
        assert_eq!(ob, db);
    }

    #[test]
    fn block_flops_sit_between_diag_and_dense() {
        let n = 16;
        let block = flops_combine_block(n, 2);
        assert!(block > flops_combine_diag(n));
        assert!(flops_combine(n) > 10 * block, "dense {} vs block {block}", flops_combine(n));
        assert_eq!(flops_combine_block(8, 2), 4 * (16 + 8 + 2));
        assert_eq!(flops_apply_block(8, 2, 10), 400);
    }

    /// The lane-vectorized diagonal compose must match the scalar reference
    /// **bitwise** at awkward shapes: n = 1, odd n (tail lanes), n just
    /// below/above a lane multiple, and large n — for both scalar types.
    #[test]
    fn combine_diag_simd_matches_scalar_bitwise() {
        let w = simd::LANE_BLOCK;
        for &n in &[1usize, 2, 3, 5, 7, w - 1, w, w + 1, 2 * w - 1, 2 * w, 2 * w + 3, 100] {
            let mut rng = Rng::new(1000 + n as u64);
            let mut al = vec![0.0f64; n];
            let mut bl = vec![0.0f64; n];
            let mut ae = vec![0.0f64; n];
            let mut be = vec![0.0f64; n];
            rng.fill_normal(&mut al, 1.0);
            rng.fill_normal(&mut bl, 1.0);
            rng.fill_normal(&mut ae, 1.0);
            rng.fill_normal(&mut be, 1.0);
            let mut oa_s = vec![0.0f64; n];
            let mut ob_s = vec![0.0f64; n];
            let mut oa_v = vec![0.0f64; n];
            let mut ob_v = vec![0.0f64; n];
            combine_diag_scalar(&al, &bl, &ae, &be, &mut oa_s, &mut ob_s, n);
            combine_diag(&al, &bl, &ae, &be, &mut oa_v, &mut ob_v, n);
            assert_eq!(oa_s, oa_v, "n={n} a");
            assert_eq!(ob_s, ob_v, "n={n} b");

            // f32 lanes too (a full F32x8 register path)
            let al32: Vec<f32> = al.iter().map(|&v| v as f32).collect();
            let bl32: Vec<f32> = bl.iter().map(|&v| v as f32).collect();
            let ae32: Vec<f32> = ae.iter().map(|&v| v as f32).collect();
            let be32: Vec<f32> = be.iter().map(|&v| v as f32).collect();
            let mut oa_s32 = vec![0.0f32; n];
            let mut ob_s32 = vec![0.0f32; n];
            let mut oa_v32 = vec![0.0f32; n];
            let mut ob_v32 = vec![0.0f32; n];
            combine_diag_scalar(&al32, &bl32, &ae32, &be32, &mut oa_s32, &mut ob_s32, n);
            combine_diag(&al32, &bl32, &ae32, &be32, &mut oa_v32, &mut ob_v32, n);
            assert_eq!(oa_s32, oa_v32, "n={n} a (f32)");
            assert_eq!(ob_s32, ob_v32, "n={n} b (f32)");
        }
    }

    /// The across-units Block(2) kernel must match the scalar tile loops
    /// bitwise at unit counts straddling the lane width.
    #[test]
    fn combine_block2_simd_matches_scalar_bitwise() {
        let w = simd::LANE_BLOCK;
        for &nb in &[1usize, 2, w - 1, w, w + 1, 2 * w, 2 * w + 5] {
            let n = 2 * nb;
            let mut rng = Rng::new(2000 + nb as u64);
            let mut al = vec![0.0f64; n * 2];
            let mut bl = vec![0.0f64; n];
            let mut ae = vec![0.0f64; n * 2];
            let mut be = vec![0.0f64; n];
            rng.fill_normal(&mut al, 1.0);
            rng.fill_normal(&mut bl, 1.0);
            rng.fill_normal(&mut ae, 1.0);
            rng.fill_normal(&mut be, 1.0);
            let mut oa_s = vec![0.0f64; n * 2];
            let mut ob_s = vec![0.0f64; n];
            let mut oa_v = vec![0.0f64; n * 2];
            let mut ob_v = vec![0.0f64; n];
            combine_block_scalar(&al, &bl, &ae, &be, &mut oa_s, &mut ob_s, n, 2);
            combine_block(&al, &bl, &ae, &be, &mut oa_v, &mut ob_v, n, 2);
            assert_eq!(oa_s, oa_v, "nb={nb} a");
            assert_eq!(ob_s, ob_v, "nb={nb} b");
        }
    }

    /// The cache-blocked dense compose must match the scalar reference
    /// bitwise across tile-straddling sizes.
    #[test]
    fn combine_dense_simd_matches_scalar_bitwise() {
        for &n in &[1usize, 3, 7, 8, 9, 16, 17, 64, 65] {
            let mut rng = Rng::new(3000 + n as u64);
            let mut al = vec![0.0f64; n * n];
            let mut bl = vec![0.0f64; n];
            let mut ae = vec![0.0f64; n * n];
            let mut be = vec![0.0f64; n];
            rng.fill_normal(&mut al, 1.0);
            rng.fill_normal(&mut bl, 1.0);
            rng.fill_normal(&mut ae, 1.0);
            rng.fill_normal(&mut be, 1.0);
            let mut oa_s = vec![0.0f64; n * n];
            let mut ob_s = vec![0.0f64; n];
            let mut oa_v = vec![0.0f64; n * n];
            let mut ob_v = vec![0.0f64; n];
            combine_scalar(&al, &bl, &ae, &be, &mut oa_s, &mut ob_s, n);
            combine(&al, &bl, &ae, &be, &mut oa_v, &mut ob_v, n);
            assert_eq!(oa_s, oa_v, "n={n} a");
            assert_eq!(ob_s, ob_v, "n={n} b");
        }
    }

    /// Structural pins on the schedule chooser (limit behavior, not exact
    /// constants): single-thread → sequential; long sequences → chunked;
    /// the starved region picks CR exactly when the modeled log-depth sweep
    /// beats the sequential replay — cheap diagonal combines at high thread
    /// counts do, expensive dense combines do not.
    #[test]
    fn schedule_chooser_limits() {
        let n = 16;
        let dc = flops_combine(n);
        let da = flops_apply(n, 1);
        let gc = flops_combine_diag(n);
        let ga = flops_apply_diag(n, 1);
        // threads <= 1 → sequential, any structure
        assert_eq!(choose_scan_schedule(1000, 1, gc, ga), ScanSchedule::Sequential);
        // amortized region → chunked, any structure
        assert_eq!(
            choose_scan_schedule(PAR_CROSSOVER_STEPS_PER_THREAD * 8, 8, gc, ga),
            ScanSchedule::Chunked
        );
        assert_eq!(choose_scan_schedule(100_000, 8, dc, da), ScanSchedule::Chunked);
        // starved region, diagonal, threads ≈ len → CR wins the depth race
        assert_eq!(choose_scan_schedule(32, 16, gc, ga), ScanSchedule::CyclicReduction);
        // starved region, dense, modest lanes → compose cost sinks CR
        assert_eq!(choose_scan_schedule(32, 16, dc, da), ScanSchedule::Sequential);
        // tiny scans never parallelize
        assert_eq!(choose_scan_schedule(2, 16, gc, ga), ScanSchedule::Sequential);
    }

    /// A dispatched threads ≈ T diagonal solve really takes the cyclic-
    /// reduction path, and the dispatch is visible in the always-on
    /// schedule counters (delta ≥ 1: other tests in the binary may also
    /// dispatch scans concurrently, so exact equality is not assertable).
    #[test]
    fn starved_diag_dispatch_selects_cr_and_is_counted() {
        use crate::telemetry::{counter_get, Counter};
        let n = 16;
        let (len, threads) = (32, 16);
        // Precondition: this point sits in the CR region of the chooser.
        assert_eq!(
            choose_scan_schedule(len, threads, flops_combine_diag(n), flops_apply_diag(n, 1)),
            ScanSchedule::CyclicReduction
        );
        let mut rng = Rng::new(901);
        let mut a = vec![0.0f64; len * n];
        let mut b = vec![0.0f64; len * n];
        rng.fill_normal(&mut a, 0.5);
        rng.fill_normal(&mut b, 1.0);
        let y0 = vec![0.0f64; n];
        let mut out = vec![0.0f64; len * n];
        let before = counter_get(Counter::ScanCyclicReduction);
        let mut ws = ScanWorkspace::new();
        par_diag_scan_apply_ws(&a, &b, &y0, &mut out, n, len, threads, &mut ws);
        let after = counter_get(Counter::ScanCyclicReduction);
        assert!(after >= before + 1, "CR dispatch not counted: {before} -> {after}");
        // And the dispatched result matches the sequential reference.
        let mut reference = vec![0.0f64; len * n];
        seq_scan_reverse_sanity(&a, &b, &y0, &mut reference, n, len);
        for (i, (&got, &want)) in out.iter().zip(reference.iter()).enumerate() {
            assert!((got - want).abs() < 1e-10, "elem {i}: {got} vs {want}");
        }
    }

    /// Scalar reference recurrence for the CR dispatch test:
    /// y_i = a_i ⊙ y_{i−1} + b_i.
    fn seq_scan_reverse_sanity(
        a: &[f64],
        b: &[f64],
        y0: &[f64],
        out: &mut [f64],
        n: usize,
        len: usize,
    ) {
        let mut prev = y0.to_vec();
        for i in 0..len {
            for j in 0..n {
                out[i * n + j] = a[i * n + j] * prev[j] + b[i * n + j];
            }
            prev.copy_from_slice(&out[i * n..(i + 1) * n]);
        }
    }

    #[test]
    fn active_indices_respects_mask() {
        assert_eq!(active_indices(3, None), vec![0, 1, 2]);
        assert_eq!(active_indices(4, Some(&[true, false, false, true])), vec![0, 3]);
        assert!(active_indices(2, Some(&[false, false])).is_empty());
    }

    #[test]
    fn batch_chunks_cover_grid_exactly_once() {
        for &(t_len, n_active, threads) in
            &[(100usize, 1usize, 4usize), (100, 8, 2), (257, 3, 8), (10, 4, 8), (5, 2, 1)]
        {
            let seqs: Vec<usize> = (0..n_active).collect();
            let chunks = plan_batch_chunks(t_len, &seqs, threads, n_active);
            // each sequence's chunks tile [0, t_len) contiguously
            for &s in &seqs {
                let mut covered = 0;
                for &(cs, lo, hi) in &chunks {
                    if cs == s {
                        assert_eq!(lo, covered, "non-contiguous chunk for seq {s}");
                        assert!(hi > lo);
                        covered = hi;
                    }
                }
                assert_eq!(covered, t_len, "seq {s} not fully covered");
            }
        }
    }

    #[test]
    fn batch_chunks_single_seq_matches_legacy_chunking() {
        // B=1 must reproduce the single-sequence planner: `threads` chunks of
        // ceil(T/threads), collapsing to one chunk when T < 4·threads.
        let chunks = plan_batch_chunks(1000, &[0], 4, 1);
        assert_eq!(chunks.len(), 4);
        assert_eq!(chunks[0], (0, 0, 250));
        assert_eq!(chunks[3], (0, 750, 1000));
        let short = plan_batch_chunks(10, &[0], 4, 1);
        assert_eq!(short, vec![(0, 0, 10)]);
    }

    #[test]
    fn batch_chunks_many_seqs_one_chunk_each() {
        // B ≥ threads: whole-sequence granularity (no intra-seq splitting).
        let seqs: Vec<usize> = (0..8).collect();
        let chunks = plan_batch_chunks(10_000, &seqs, 2, 8);
        assert_eq!(chunks.len(), 8);
        assert!(chunks.iter().all(|&(_, lo, hi)| lo == 0 && hi == 10_000));
    }

    #[test]
    fn batch_chunks_invariant_to_masking_state() {
        // The per-sequence decomposition must not change when neighbours
        // freeze: cps is keyed on the total batch, not the active count.
        let full: Vec<usize> = (0..4).collect();
        let all = plan_batch_chunks(1000, &full, 8, 4);
        let masked = plan_batch_chunks(1000, &[2], 8, 4);
        let seq2_full: Vec<_> = all.iter().filter(|&&(s, _, _)| s == 2).collect();
        let seq2_masked: Vec<_> = masked.iter().collect();
        assert_eq!(seq2_full.len(), seq2_masked.len());
        for (a, b) in seq2_full.iter().zip(seq2_masked.iter()) {
            assert_eq!(a, b, "masking changed a sequence's chunk decomposition");
        }
    }
}
