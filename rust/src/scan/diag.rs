//! O(n)-per-element scan kernels for **diagonal** affine elements.
//!
//! When every propagator is `A_i = diag(a_i)` the eq. (10) monoid closes
//! over packed diagonals: compose is `a_l ⊙ a_e` and apply is
//! `a_i ⊙ y + b_i`, both O(n). This is the INVLIN fast path used by
//! natively-diagonal cells ([`crate::cells::IndRnn`]) and by quasi-DEER
//! mode ([`crate::deer::JacobianMode::DiagonalApprox`]), which replaces the
//! dense O(n³) compose of §3.5 with a linear-cost one (Gonzalez et al.
//! 2024; Danieli et al. 2025).
//!
//! Layout: `a` and `b` are both `len·n`, `a[i·n + j]` the j-th diagonal
//! entry of step i. No n×n temporaries are materialized anywhere — the
//! whole path is O(T·n) memory and O(T·n) work.

use super::cr::{par_diag_scan_apply_cr_ws, par_diag_scan_reverse_cr_ws};
use super::{
    choose_scan_schedule_observed, flops_apply_diag, flops_combine_diag, ScanSchedule, ScanWorkspace,
};
use crate::util::scalar::Scalar;

/// Sequential `y_i = a_i ⊙ y_{i−1} + b_i` with `y_{−1} = y0`.
pub fn seq_diag_scan_apply<S: Scalar>(
    a: &[S],
    b: &[S],
    y0: &[S],
    out: &mut [S],
    n: usize,
    len: usize,
) {
    debug_assert_eq!(a.len(), len * n);
    debug_assert_eq!(b.len(), len * n);
    debug_assert_eq!(out.len(), len * n);
    if len == 0 {
        return;
    }
    {
        let (head, _) = out.split_at_mut(n);
        for j in 0..n {
            head[j] = a[j] * y0[j] + b[j];
        }
    }
    for i in 1..len {
        let (prev_part, cur_part) = out.split_at_mut(i * n);
        let prev = &prev_part[(i - 1) * n..];
        let cur = &mut cur_part[..n];
        let ai = &a[i * n..(i + 1) * n];
        let bi = &b[i * n..(i + 1) * n];
        for j in 0..n {
            cur[j] = ai[j] * prev[j] + bi[j];
        }
    }
}

/// Sequential dual scan `λ_i = g_i + a_{i+1} ⊙ λ_{i+1}` (diagonal ⇒ the
/// transpose in eq. 7 is a no-op), `λ_{L−1} = g_{L−1}`.
pub fn seq_diag_scan_reverse<S: Scalar>(a: &[S], g: &[S], out: &mut [S], n: usize, len: usize) {
    debug_assert_eq!(a.len(), len * n);
    debug_assert_eq!(g.len(), len * n);
    debug_assert_eq!(out.len(), len * n);
    if len == 0 {
        return;
    }
    out[(len - 1) * n..].copy_from_slice(&g[(len - 1) * n..]);
    for i in (0..len - 1).rev() {
        let a_next = &a[(i + 1) * n..(i + 2) * n];
        let (cur_part, next_part) = out.split_at_mut((i + 1) * n);
        let next = &next_part[..n];
        let cur = &mut cur_part[i * n..];
        let gi = &g[i * n..(i + 1) * n];
        for j in 0..n {
            cur[j] = gi[j] + a_next[j] * next[j];
        }
    }
}

/// Compose a contiguous range of diagonal elements into one `(a, b)` pair:
/// `a = a_{hi−1} ⊙ ··· ⊙ a_{lo}`, `b` the matching offset. O(n·(hi−lo)).
pub fn compose_range_diag<S: Scalar>(
    a: &[S],
    b: &[S],
    lo: usize,
    hi: usize,
    a_out: &mut [S],
    b_out: &mut [S],
    n: usize,
) {
    for v in a_out.iter_mut() {
        *v = S::one();
    }
    for v in b_out.iter_mut() {
        *v = S::zero();
    }
    for i in lo..hi {
        let ai = &a[i * n..(i + 1) * n];
        let bi = &b[i * n..(i + 1) * n];
        for j in 0..n {
            b_out[j] = ai[j] * b_out[j] + bi[j];
            a_out[j] = ai[j] * a_out[j];
        }
    }
}

/// Parallel diagonal forward scan over `threads` workers (same three-phase
/// schedule as [`super::par::par_scan_apply`], every phase O(n) per element).
pub fn par_diag_scan_apply<S: Scalar>(
    a: &[S],
    b: &[S],
    y0: &[S],
    out: &mut [S],
    n: usize,
    len: usize,
    threads: usize,
) {
    let mut ws = ScanWorkspace::new();
    par_diag_scan_apply_ws(a, b, y0, out, n, len, threads, &mut ws);
}

/// [`par_diag_scan_apply`] with a reusable workspace.
#[allow(clippy::too_many_arguments)]
pub fn par_diag_scan_apply_ws<S: Scalar>(
    a: &[S],
    b: &[S],
    y0: &[S],
    out: &mut [S],
    n: usize,
    len: usize,
    threads: usize,
    ws: &mut ScanWorkspace<S>,
) {
    match choose_scan_schedule_observed(len, threads, flops_combine_diag(n), flops_apply_diag(n, 1)) {
        ScanSchedule::Sequential => {
            seq_diag_scan_apply(a, b, y0, out, n, len);
            return;
        }
        ScanSchedule::CyclicReduction => {
            par_diag_scan_apply_cr_ws(a, b, y0, out, n, len, threads, ws);
            return;
        }
        ScanSchedule::Chunked => {}
    }
    let chunks = threads;
    let chunk_len = len.div_ceil(chunks);
    ws.ensure(chunks * n, chunks * n, chunks * n);

    // Phase 1: per-chunk composition (packed diagonals, O(n) per element).
    {
        let comp: Vec<(&mut [S], &mut [S])> = ws.comp_a[..chunks * n]
            .chunks_mut(n)
            .zip(ws.comp_b[..chunks * n].chunks_mut(n))
            .collect();
        std::thread::scope(|scope| {
            for (c, (ca, cb)) in comp.into_iter().enumerate() {
                let lo = (c * chunk_len).min(len);
                let hi = ((c + 1) * chunk_len).min(len);
                scope.spawn(move || {
                    compose_range_diag(a, b, lo, hi, ca, cb, n);
                });
            }
        });
    }

    // Phase 2: sequential carry over chunk entry states (O(n·C)).
    let (comp_a, comp_b) = (&ws.comp_a, &ws.comp_b);
    let entries = &mut ws.carry[..chunks * n];
    entries[..n].copy_from_slice(y0);
    for c in 0..chunks - 1 {
        let (head, tail) = entries.split_at_mut((c + 1) * n);
        let prev = &head[c * n..];
        let next = &mut tail[..n];
        for j in 0..n {
            next[j] = comp_a[c * n + j] * prev[j] + comp_b[c * n + j];
        }
    }

    // Phase 3: per-chunk apply, in parallel.
    {
        let entries = &ws.carry;
        let mut out_chunks: Vec<&mut [S]> = Vec::with_capacity(chunks);
        let mut rest = out;
        for c in 0..chunks {
            let lo = (c * chunk_len).min(len);
            let hi = ((c + 1) * chunk_len).min(len);
            let (head, tail) = rest.split_at_mut((hi - lo) * n);
            out_chunks.push(head);
            rest = tail;
        }
        std::thread::scope(|scope| {
            for (c, out_c) in out_chunks.into_iter().enumerate() {
                let lo = (c * chunk_len).min(len);
                let hi = ((c + 1) * chunk_len).min(len);
                let entry = &entries[c * n..(c + 1) * n];
                scope.spawn(move || {
                    seq_diag_scan_apply(
                        &a[lo * n..hi * n],
                        &b[lo * n..hi * n],
                        entry,
                        out_c,
                        n,
                        hi - lo,
                    );
                });
            }
        });
    }
}

/// Fused batched diagonal forward scan over B independent sequences in the
/// `[B, T, n]` layout (see the batched-layout notes in [`crate::scan`]).
/// `active` masks sequences in place — masked slabs of `out` are neither
/// read nor written. With B ≥ threads each worker runs the plain
/// O(n)-per-element sequential kernel over whole sequences; with
/// B < threads the spare lanes split inside sequences. All scheduling is
/// keyed on the total B, never the active count, so results are
/// bit-reproducible across masking states.
#[allow(clippy::too_many_arguments)]
pub fn par_diag_scan_apply_batch_ws<S: Scalar>(
    a: &[S],
    b: &[S],
    y0s: &[S],
    out: &mut [S],
    n: usize,
    t_len: usize,
    batch: usize,
    active: Option<&[bool]>,
    threads: usize,
    ws: &mut ScanWorkspace<S>,
) {
    debug_assert_eq!(a.len(), batch * t_len * n);
    debug_assert_eq!(b.len(), batch * t_len * n);
    debug_assert_eq!(y0s.len(), batch * n);
    debug_assert_eq!(out.len(), batch * t_len * n);
    let idx = crate::scan::active_indices(batch, active);
    if idx.is_empty() || t_len == 0 {
        return;
    }
    let sn = t_len * n;
    if batch == 1 {
        // the single-sequence case: intra-sequence three-phase scan with the
        // caller's reusable workspace
        par_diag_scan_apply_ws(a, b, y0s, out, n, t_len, threads, ws);
        return;
    }
    // Scheduling is keyed on the TOTAL batch size (not the active count) so
    // a sequence's accumulation order never changes as neighbours freeze —
    // batched results stay bit-reproducible across masking states.
    let mut slabs: Vec<Option<&mut [S]>> = out.chunks_mut(sn).map(Some).collect();
    if threads <= 1 {
        for &s in &idx {
            let o = slabs[s].take().unwrap();
            seq_diag_scan_apply(
                &a[s * sn..(s + 1) * sn],
                &b[s * sn..(s + 1) * sn],
                &y0s[s * n..(s + 1) * n],
                o,
                n,
                t_len,
            );
        }
    } else if batch >= threads {
        let workers = threads.min(idx.len());
        let mut buckets: Vec<Vec<(usize, &mut [S])>> = (0..workers).map(|_| Vec::new()).collect();
        for (k, &s) in idx.iter().enumerate() {
            buckets[k % workers].push((s, slabs[s].take().unwrap()));
        }
        std::thread::scope(|scope| {
            for bucket in buckets {
                scope.spawn(move || {
                    for (s, o) in bucket {
                        seq_diag_scan_apply(
                            &a[s * sn..(s + 1) * sn],
                            &b[s * sn..(s + 1) * sn],
                            &y0s[s * n..(s + 1) * n],
                            o,
                            n,
                            t_len,
                        );
                    }
                });
            }
        });
    } else {
        // 1 < B < threads: fixed intra-sequence split (constant divisor B
        // keeps the decomposition masking-invariant)
        let cps = (threads / batch).max(2);
        std::thread::scope(|scope| {
            for &s in &idx {
                let o = slabs[s].take().unwrap();
                let a_s = &a[s * sn..(s + 1) * sn];
                let b_s = &b[s * sn..(s + 1) * sn];
                let y0_s = &y0s[s * n..(s + 1) * n];
                scope.spawn(move || {
                    let mut local = ScanWorkspace::new();
                    par_diag_scan_apply_ws(a_s, b_s, y0_s, o, n, t_len, cps, &mut local);
                });
            }
        });
    }
}

/// Fused batched diagonal dual scan (`[B, T, n]` layout; same scheduling
/// and masking rules as [`par_diag_scan_apply_batch_ws`]).
#[allow(clippy::too_many_arguments)]
pub fn par_diag_scan_reverse_batch_ws<S: Scalar>(
    a: &[S],
    g: &[S],
    out: &mut [S],
    n: usize,
    t_len: usize,
    batch: usize,
    active: Option<&[bool]>,
    threads: usize,
    ws: &mut ScanWorkspace<S>,
) {
    debug_assert_eq!(a.len(), batch * t_len * n);
    debug_assert_eq!(g.len(), batch * t_len * n);
    debug_assert_eq!(out.len(), batch * t_len * n);
    let idx = crate::scan::active_indices(batch, active);
    if idx.is_empty() || t_len == 0 {
        return;
    }
    let sn = t_len * n;
    if batch == 1 {
        par_diag_scan_reverse_ws(a, g, out, n, t_len, threads, ws);
        return;
    }
    let mut slabs: Vec<Option<&mut [S]>> = out.chunks_mut(sn).map(Some).collect();
    if threads <= 1 {
        for &s in &idx {
            let o = slabs[s].take().unwrap();
            seq_diag_scan_reverse(&a[s * sn..(s + 1) * sn], &g[s * sn..(s + 1) * sn], o, n, t_len);
        }
    } else if batch >= threads {
        let workers = threads.min(idx.len());
        let mut buckets: Vec<Vec<(usize, &mut [S])>> = (0..workers).map(|_| Vec::new()).collect();
        for (k, &s) in idx.iter().enumerate() {
            buckets[k % workers].push((s, slabs[s].take().unwrap()));
        }
        std::thread::scope(|scope| {
            for bucket in buckets {
                scope.spawn(move || {
                    for (s, o) in bucket {
                        seq_diag_scan_reverse(
                            &a[s * sn..(s + 1) * sn],
                            &g[s * sn..(s + 1) * sn],
                            o,
                            n,
                            t_len,
                        );
                    }
                });
            }
        });
    } else {
        let cps = (threads / batch).max(2);
        std::thread::scope(|scope| {
            for &s in &idx {
                let o = slabs[s].take().unwrap();
                let a_s = &a[s * sn..(s + 1) * sn];
                let g_s = &g[s * sn..(s + 1) * sn];
                scope.spawn(move || {
                    let mut local = ScanWorkspace::new();
                    par_diag_scan_reverse_ws(a_s, g_s, o, n, t_len, cps, &mut local);
                });
            }
        });
    }
}

/// Parallel diagonal dual scan (backward pass, eq. 7 with diagonal `A`).
pub fn par_diag_scan_reverse<S: Scalar>(
    a: &[S],
    g: &[S],
    out: &mut [S],
    n: usize,
    len: usize,
    threads: usize,
) {
    let mut ws = ScanWorkspace::new();
    par_diag_scan_reverse_ws(a, g, out, n, len, threads, &mut ws);
}

/// [`par_diag_scan_reverse`] with a reusable workspace.
pub fn par_diag_scan_reverse_ws<S: Scalar>(
    a: &[S],
    g: &[S],
    out: &mut [S],
    n: usize,
    len: usize,
    threads: usize,
    ws: &mut ScanWorkspace<S>,
) {
    match choose_scan_schedule_observed(len, threads, flops_combine_diag(n), flops_apply_diag(n, 1)) {
        ScanSchedule::Sequential => {
            seq_diag_scan_reverse(a, g, out, n, len);
            return;
        }
        ScanSchedule::CyclicReduction => {
            par_diag_scan_reverse_cr_ws(a, g, out, n, len, threads, ws);
            return;
        }
        ScanSchedule::Chunked => {}
    }
    let chunks = threads;
    let chunk_len = len.div_ceil(chunks);
    ws.ensure(chunks * n, chunks * n, chunks * n);

    // Phase 1: per-chunk reverse composition. For chunk [lo, hi):
    // λ_{lo} = m_c ⊙ λ_{hi} + v_c, built right-to-left.
    {
        let comp: Vec<(&mut [S], &mut [S])> = ws.comp_a[..chunks * n]
            .chunks_mut(n)
            .zip(ws.comp_b[..chunks * n].chunks_mut(n))
            .collect();
        std::thread::scope(|scope| {
            for (c, (cm, cv)) in comp.into_iter().enumerate() {
                let lo = (c * chunk_len).min(len);
                let hi = ((c + 1) * chunk_len).min(len);
                scope.spawn(move || {
                    for v in cm.iter_mut() {
                        *v = S::one();
                    }
                    for v in cv.iter_mut() {
                        *v = S::zero();
                    }
                    for i in (lo..hi).rev() {
                        if i + 1 < len {
                            let an = &a[(i + 1) * n..(i + 2) * n];
                            let gi = &g[i * n..(i + 1) * n];
                            for j in 0..n {
                                cv[j] = an[j] * cv[j] + gi[j];
                                cm[j] = an[j] * cm[j];
                            }
                        } else {
                            // last element of the whole sequence: λ = g only
                            for v in cm.iter_mut() {
                                *v = S::zero();
                            }
                            cv.copy_from_slice(&g[i * n..(i + 1) * n]);
                        }
                    }
                });
            }
        });
    }

    // Phase 2: carry λ at chunk boundaries, right to left.
    let (comp_m, comp_v) = (&ws.comp_a, &ws.comp_b);
    let exits = &mut ws.carry[..chunks * n];
    for v in exits[(chunks - 1) * n..].iter_mut() {
        *v = S::zero();
    }
    for c in (1..chunks).rev() {
        let (head, tail) = exits.split_at_mut(c * n);
        let cur = &tail[..n];
        let prev = &mut head[(c - 1) * n..];
        for j in 0..n {
            prev[j] = comp_m[c * n + j] * cur[j] + comp_v[c * n + j];
        }
    }

    // Phase 3: per-chunk reverse apply.
    {
        let exits = &ws.carry;
        let mut out_chunks: Vec<&mut [S]> = Vec::with_capacity(chunks);
        let mut rest = out;
        for c in 0..chunks {
            let lo = (c * chunk_len).min(len);
            let hi = ((c + 1) * chunk_len).min(len);
            let (head, tail) = rest.split_at_mut((hi - lo) * n);
            out_chunks.push(head);
            rest = tail;
        }
        std::thread::scope(|scope| {
            for (c, out_c) in out_chunks.into_iter().enumerate() {
                let lo = (c * chunk_len).min(len);
                let hi = ((c + 1) * chunk_len).min(len);
                let exit = &exits[c * n..(c + 1) * n];
                scope.spawn(move || {
                    let mut next = exit.to_vec();
                    for i in (lo..hi).rev() {
                        let li = i - lo;
                        let oc = &mut out_c[li * n..(li + 1) * n];
                        let gi = &g[i * n..(i + 1) * n];
                        if i + 1 < len {
                            let an = &a[(i + 1) * n..(i + 2) * n];
                            for j in 0..n {
                                oc[j] = gi[j] + an[j] * next[j];
                            }
                        } else {
                            oc.copy_from_slice(gi);
                        }
                        next.copy_from_slice(oc);
                    }
                });
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scan::seq::{seq_scan_apply, seq_scan_reverse};
    use crate::util::rng::Rng;

    fn random_diag(n: usize, len: usize, seed: u64) -> (Vec<f64>, Vec<f64>, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let mut a = vec![0.0; len * n];
        let mut b = vec![0.0; len * n];
        let mut y0 = vec![0.0; n];
        rng.fill_normal(&mut a, 0.6);
        rng.fill_normal(&mut b, 1.0);
        rng.fill_normal(&mut y0, 1.0);
        (a, b, y0)
    }

    /// Embed a packed diagonal sequence into dense n×n matrices.
    fn embed_dense(a: &[f64], n: usize, len: usize) -> Vec<f64> {
        let mut dense = vec![0.0; len * n * n];
        for i in 0..len {
            for j in 0..n {
                dense[i * n * n + j * n + j] = a[i * n + j];
            }
        }
        dense
    }

    #[test]
    fn diag_forward_matches_dense_scan() {
        for &(n, len) in &[(1usize, 40usize), (3, 111), (16, 64)] {
            let (a, b, y0) = random_diag(n, len, 7 + n as u64);
            let dense = embed_dense(&a, n, len);
            let mut out_dense = vec![0.0; len * n];
            let mut out_diag = vec![0.0; len * n];
            seq_scan_apply(&dense, &b, &y0, &mut out_dense, n, len);
            seq_diag_scan_apply(&a, &b, &y0, &mut out_diag, n, len);
            for (x, y) in out_dense.iter().zip(out_diag.iter()) {
                assert!((x - y).abs() < 1e-12, "n={n} len={len}: {x} vs {y}");
            }
        }
    }

    #[test]
    fn diag_reverse_matches_dense_scan() {
        for &(n, len) in &[(1usize, 33usize), (4, 90), (8, 57)] {
            let (a, g, _) = random_diag(n, len, 31 + n as u64);
            let dense = embed_dense(&a, n, len);
            let mut out_dense = vec![0.0; len * n];
            let mut out_diag = vec![0.0; len * n];
            seq_scan_reverse(&dense, &g, &mut out_dense, n, len);
            seq_diag_scan_reverse(&a, &g, &mut out_diag, n, len);
            for (x, y) in out_dense.iter().zip(out_diag.iter()) {
                assert!((x - y).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn par_matches_seq_forward_all_thread_counts() {
        for &threads in &[1usize, 2, 4, 8] {
            for &(n, len) in &[(2usize, 257usize), (5, 100), (16, 1000)] {
                let (a, b, y0) = random_diag(n, len, threads as u64 * 91 + n as u64);
                let mut out_s = vec![0.0; len * n];
                let mut out_p = vec![0.0; len * n];
                seq_diag_scan_apply(&a, &b, &y0, &mut out_s, n, len);
                par_diag_scan_apply(&a, &b, &y0, &mut out_p, n, len, threads);
                for (i, (x, y)) in out_s.iter().zip(out_p.iter()).enumerate() {
                    assert!(
                        (x - y).abs() < 1e-9,
                        "t={threads} n={n} len={len} i={i}: {x} vs {y}"
                    );
                }
            }
        }
    }

    #[test]
    fn par_matches_seq_reverse_all_thread_counts() {
        for &threads in &[1usize, 2, 4, 8] {
            for &(n, len) in &[(2usize, 300usize), (4, 65), (16, 513)] {
                let (a, g, _) = random_diag(n, len, threads as u64 * 17 + len as u64);
                let mut out_s = vec![0.0; len * n];
                let mut out_p = vec![0.0; len * n];
                seq_diag_scan_reverse(&a, &g, &mut out_s, n, len);
                par_diag_scan_reverse(&a, &g, &mut out_p, n, len, threads);
                for (i, (x, y)) in out_s.iter().zip(out_p.iter()).enumerate() {
                    assert!(
                        (x - y).abs() < 1e-9,
                        "t={threads} n={n} len={len} i={i}: {x} vs {y}"
                    );
                }
            }
        }
    }

    #[test]
    fn compose_range_diag_equals_endpoint() {
        let (n, len) = (3, 17);
        let (a, b, y0) = random_diag(n, len, 4);
        let mut out = vec![0.0; len * n];
        seq_diag_scan_apply(&a, &b, &y0, &mut out, n, len);
        let mut ca = vec![0.0; n];
        let mut cb = vec![0.0; n];
        compose_range_diag(&a, &b, 0, len, &mut ca, &mut cb, n);
        for j in 0..n {
            let y_end = ca[j] * y0[j] + cb[j];
            assert!((y_end - out[(len - 1) * n + j]).abs() < 1e-10);
        }
    }

    #[test]
    fn empty_and_single() {
        let mut out: Vec<f64> = vec![];
        seq_diag_scan_apply::<f64>(&[], &[], &[1.0], &mut out, 1, 0);
        let a = vec![2.0];
        let b = vec![3.0];
        let mut out = vec![0.0];
        seq_diag_scan_apply(&a, &b, &[4.0], &mut out, 1, 1);
        assert_eq!(out, vec![11.0]);
        let mut lam = vec![0.0];
        seq_diag_scan_reverse(&a, &b, &mut lam, 1, 1);
        assert_eq!(lam, vec![3.0]);
    }

    /// One fused batched diagonal call == B independent sequential scans,
    /// across scheduling regimes, and the active mask freezes sequences.
    #[test]
    fn batch_diag_forward_matches_per_sequence_and_masks() {
        for &(n, t_len, batch, threads) in
            &[(4usize, 200usize, 6usize, 2usize), (3, 150, 2, 8), (16, 64, 4, 1)]
        {
            let mut rng = Rng::new(3000 + (n * batch * threads) as u64);
            let sn = t_len * n;
            let mut a = vec![0.0f64; batch * sn];
            let mut b = vec![0.0f64; batch * sn];
            let mut y0s = vec![0.0f64; batch * n];
            rng.fill_normal(&mut a, 0.6);
            rng.fill_normal(&mut b, 1.0);
            rng.fill_normal(&mut y0s, 1.0);

            let sentinel = -555.0f64;
            let mut active = vec![true; batch];
            active[batch - 1] = false;
            let mut got = vec![sentinel; batch * sn];
            let mut ws = ScanWorkspace::new();
            par_diag_scan_apply_batch_ws(
                &a, &b, &y0s, &mut got, n, t_len, batch, Some(&active), threads, &mut ws,
            );
            for s in 0..batch {
                let slab = &got[s * sn..(s + 1) * sn];
                if active[s] {
                    let mut want = vec![0.0f64; sn];
                    seq_diag_scan_apply(
                        &a[s * sn..(s + 1) * sn],
                        &b[s * sn..(s + 1) * sn],
                        &y0s[s * n..(s + 1) * n],
                        &mut want,
                        n,
                        t_len,
                    );
                    for (x, y) in want.iter().zip(slab.iter()) {
                        assert!((x - y).abs() < 1e-9, "B={batch} thr={threads} seq {s}");
                    }
                } else {
                    assert!(slab.iter().all(|&v| v == sentinel), "masked seq {s} written");
                }
            }
        }
    }

    #[test]
    fn batch_diag_reverse_matches_per_sequence() {
        for &(n, t_len, batch, threads) in
            &[(4usize, 180usize, 5usize, 2usize), (2, 300, 3, 8), (8, 90, 6, 1)]
        {
            let mut rng = Rng::new(4000 + (n * batch * threads) as u64);
            let sn = t_len * n;
            let mut a = vec![0.0f64; batch * sn];
            let mut g = vec![0.0f64; batch * sn];
            rng.fill_normal(&mut a, 0.6);
            rng.fill_normal(&mut g, 1.0);

            let mut want = vec![0.0f64; batch * sn];
            for s in 0..batch {
                seq_diag_scan_reverse(
                    &a[s * sn..(s + 1) * sn],
                    &g[s * sn..(s + 1) * sn],
                    &mut want[s * sn..(s + 1) * sn],
                    n,
                    t_len,
                );
            }
            let mut got = vec![0.0f64; batch * sn];
            let mut ws = ScanWorkspace::new();
            par_diag_scan_reverse_batch_ws(
                &a, &g, &mut got, n, t_len, batch, None, threads, &mut ws,
            );
            for (x, y) in want.iter().zip(got.iter()) {
                assert!((x - y).abs() < 1e-9, "B={batch} thr={threads}: {x} vs {y}");
            }
        }
    }

    #[test]
    fn workspace_reuse_across_shapes() {
        let mut ws = ScanWorkspace::new();
        for &(n, len, threads) in &[(8usize, 400usize, 8usize), (2, 64, 4), (16, 300, 2)] {
            let (a, b, y0) = random_diag(n, len, 2000 + len as u64);
            let mut out_s = vec![0.0; len * n];
            let mut out_p = vec![0.0; len * n];
            seq_diag_scan_apply(&a, &b, &y0, &mut out_s, n, len);
            par_diag_scan_apply_ws(&a, &b, &y0, &mut out_p, n, len, threads, &mut ws);
            for (x, y) in out_s.iter().zip(out_p.iter()) {
                assert!((x - y).abs() < 1e-9);
            }
        }
    }
}
