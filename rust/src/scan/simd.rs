//! Dependency-free portable SIMD for the scan compose hot path.
//!
//! The crate pins `rust-version = 1.75`, which has neither `std::simd` nor
//! external SIMD crates, so the lane types here are plain fixed-size arrays
//! wrapped in `#[repr(transparent)]` structs. That is enough: every lane op
//! is a bounds-check-free loop over a compile-time-constant width, which
//! LLVM reliably unrolls and autovectorizes on the 1.75 toolchain (SSE2
//! baseline on x86-64; wider with `-C target-cpu=native`). The *reason* the
//! scalar kernels in [`crate::scan`] did not autovectorize is not the math —
//! it is that loops indexing six independently-lengthed slices keep their
//! per-element bounds checks, which break vector codegen. Loading into
//! `[S; W]` blocks first removes every in-loop check.
//!
//! # Lane layout
//!
//! * [`F32x8`] — 8 × f32 (one AVX register, two SSE2 registers).
//! * [`F64x4`] — 4 × f64 (one AVX register, two SSE2 registers).
//! * Generic kernels over [`Scalar`] use a fixed [`LANE_BLOCK`] = 8 block
//!   width regardless of scalar type (per-type widths would need
//!   `generic_const_exprs`); the compiler splits an 8×f64 block into two
//!   4-lane registers, which costs nothing.
//!
//! Vectors shorter than a lane multiple run a **scalar tail** loop with the
//! exact per-element expression of the lane body.
//!
//! # Scalar-reference (bitwise) contract
//!
//! Every vectorized kernel here computes each output element with the same
//! floating-point expression, in the same association order, as its scalar
//! reference in [`crate::scan`] (`combine_diag_scalar`, `combine_scalar`,
//! `combine_block_scalar`). In particular:
//!
//! * multiplies and adds stay separate ops — **never** a fused
//!   multiply-add, which would change results;
//! * dot-product style reductions keep their scalar accumulation order
//!   (they vectorize across independent outputs, not within a reduction);
//! * the Block(2) kernel vectorizes **across units** (8 independent 2×2
//!   tiles per block), never within a tile, so each tile's k-order matches
//!   the scalar tile loop.
//!
//! Tests in [`crate::scan`] pin `assert_eq!` equality against the scalar
//! references at awkward shapes (n = 1, odd n, n ± 1 around a lane
//! multiple).

use crate::util::scalar::Scalar;

/// Fixed lane-block width used by the generic kernels (see module docs).
pub const LANE_BLOCK: usize = 8;

/// A `W`-wide lane of scalars. All ops are element-wise, unrolled, and
/// bounds-check-free; there is deliberately no horizontal reduction (it
/// would reassociate sums and break the bitwise contract).
#[derive(Clone, Copy, Debug)]
#[repr(transparent)]
pub struct Lanes<S, const W: usize>(pub [S; W]);

/// 8 × f32 — one AVX register.
pub type F32x8 = Lanes<f32, 8>;
/// 4 × f64 — one AVX register.
pub type F64x4 = Lanes<f64, 4>;

impl<S: Scalar, const W: usize> Lanes<S, W> {
    /// Broadcast one scalar to every lane.
    #[inline(always)]
    pub fn splat(v: S) -> Self {
        Lanes([v; W])
    }

    /// Load `W` contiguous elements from the front of `src`.
    #[inline(always)]
    pub fn load(src: &[S]) -> Self {
        let arr: [S; W] = src[..W].try_into().expect("lane load needs W elements");
        Lanes(arr)
    }

    /// Store the lanes to the front of `dst`.
    #[inline(always)]
    pub fn store(self, dst: &mut [S]) {
        dst[..W].copy_from_slice(&self.0);
    }

    /// Element-wise product.
    #[inline(always)]
    pub fn mul(self, o: Self) -> Self {
        let mut r = self.0;
        for j in 0..W {
            r[j] = r[j] * o.0[j];
        }
        Lanes(r)
    }

    /// Element-wise sum.
    #[inline(always)]
    pub fn add(self, o: Self) -> Self {
        let mut r = self.0;
        for j in 0..W {
            r[j] = r[j] + o.0[j];
        }
        Lanes(r)
    }

    /// `self * m + a`, computed as separate multiply then add (not fused) so
    /// results stay bitwise identical to the scalar kernels.
    #[inline(always)]
    pub fn mul_add_separate(self, m: Self, a: Self) -> Self {
        let mut r = self.0;
        for j in 0..W {
            r[j] = r[j] * m.0[j] + a.0[j];
        }
        Lanes(r)
    }
}

/// Vectorized diagonal compose: `a_out = a_l ⊙ a_e`, `b_out = a_l ⊙ b_e + b_l`
/// in [`LANE_BLOCK`]-wide blocks with a scalar tail. Bitwise identical to
/// [`crate::scan::combine_diag_scalar`] (element-wise ops carry no
/// accumulation order to preserve).
#[inline]
pub fn combine_diag_lanes<S: Scalar>(
    a_later: &[S],
    b_later: &[S],
    a_earlier: &[S],
    b_earlier: &[S],
    a_out: &mut [S],
    b_out: &mut [S],
    n: usize,
) {
    const W: usize = LANE_BLOCK;
    let main = n - n % W;
    let mut i = 0;
    while i < main {
        let al = Lanes::<S, W>::load(&a_later[i..]);
        let bl = Lanes::<S, W>::load(&b_later[i..]);
        let ae = Lanes::<S, W>::load(&a_earlier[i..]);
        let be = Lanes::<S, W>::load(&b_earlier[i..]);
        al.mul(ae).store(&mut a_out[i..]);
        be.mul(al).add(bl).store(&mut b_out[i..]);
        i += W;
    }
    for i in main..n {
        a_out[i] = a_later[i] * a_earlier[i];
        b_out[i] = a_later[i] * b_earlier[i] + b_later[i];
    }
}

/// One scalar Block(2) tile compose, shared by the vectorized kernel's tail
/// and the scalar reference: the k-loop of the generic tile multiply
/// unrolled at k = 2 (identical association order).
#[inline(always)]
fn block2_tile<S: Scalar>(al: &[S], ae: &[S], be: &[S], bl: &[S], ao: &mut [S], bo: &mut [S]) {
    // A_out = A_l · A_e, k = 0 term first, then k = 1 (the scalar kernel's
    // `crow[c] += aik * brow[c]` order starting from zero).
    ao[0] = al[0] * ae[0] + al[1] * ae[2];
    ao[1] = al[0] * ae[1] + al[1] * ae[3];
    ao[2] = al[2] * ae[0] + al[3] * ae[2];
    ao[3] = al[2] * ae[1] + al[3] * ae[3];
    // b_out = A_l · b_e + b_l, row dot in ascending column order.
    bo[0] = al[0] * be[0] + al[1] * be[1] + bl[0];
    bo[1] = al[2] * be[0] + al[3] * be[1] + bl[1];
}

/// Vectorized Block(2) compose: [`LANE_BLOCK`] independent 2×2 tiles per
/// block, vectorized **across units** — lane j holds tile-entry `e` of unit
/// `u0 + j` — never within a tile, so each tile's two-term sums keep the
/// scalar association order. Bitwise identical to
/// [`crate::scan::combine_block_scalar`] at k = 2.
#[inline]
pub fn combine_block2_lanes<S: Scalar>(
    a_later: &[S],
    b_later: &[S],
    a_earlier: &[S],
    b_earlier: &[S],
    a_out: &mut [S],
    b_out: &mut [S],
    n: usize,
) {
    const W: usize = LANE_BLOCK;
    debug_assert_eq!(n % 2, 0);
    let nb = n / 2; // number of 2×2 tiles
    let main = nb - nb % W;
    let mut u = 0;
    while u < main {
        // Strided gather: tile fields of units u..u+W into lane registers.
        let mut la = [S::zero(); W];
        let mut lb = [S::zero(); W];
        let mut lc = [S::zero(); W];
        let mut ld = [S::zero(); W];
        let mut ea = [S::zero(); W];
        let mut eb = [S::zero(); W];
        let mut ec = [S::zero(); W];
        let mut ed = [S::zero(); W];
        let mut b0 = [S::zero(); W];
        let mut b1 = [S::zero(); W];
        let mut l0 = [S::zero(); W];
        let mut l1 = [S::zero(); W];
        for j in 0..W {
            let t = (u + j) * 4;
            la[j] = a_later[t];
            lb[j] = a_later[t + 1];
            lc[j] = a_later[t + 2];
            ld[j] = a_later[t + 3];
            ea[j] = a_earlier[t];
            eb[j] = a_earlier[t + 1];
            ec[j] = a_earlier[t + 2];
            ed[j] = a_earlier[t + 3];
            let p = (u + j) * 2;
            b0[j] = b_earlier[p];
            b1[j] = b_earlier[p + 1];
            l0[j] = b_later[p];
            l1[j] = b_later[p + 1];
        }
        // Per-lane tile math — same expressions as `block2_tile`.
        let mut oa = [S::zero(); W];
        let mut ob = [S::zero(); W];
        let mut oc = [S::zero(); W];
        let mut od = [S::zero(); W];
        let mut o0 = [S::zero(); W];
        let mut o1 = [S::zero(); W];
        for j in 0..W {
            oa[j] = la[j] * ea[j] + lb[j] * ec[j];
            ob[j] = la[j] * eb[j] + lb[j] * ed[j];
            oc[j] = lc[j] * ea[j] + ld[j] * ec[j];
            od[j] = lc[j] * eb[j] + ld[j] * ed[j];
            o0[j] = la[j] * b0[j] + lb[j] * b1[j] + l0[j];
            o1[j] = lc[j] * b0[j] + ld[j] * b1[j] + l1[j];
        }
        // Scatter back.
        for j in 0..W {
            let t = (u + j) * 4;
            a_out[t] = oa[j];
            a_out[t + 1] = ob[j];
            a_out[t + 2] = oc[j];
            a_out[t + 3] = od[j];
            let p = (u + j) * 2;
            b_out[p] = o0[j];
            b_out[p + 1] = o1[j];
        }
        u += W;
    }
    for u in main..nb {
        let t = u * 4;
        let p = u * 2;
        block2_tile(
            &a_later[t..t + 4],
            &a_earlier[t..t + 4],
            &b_earlier[p..p + 2],
            &b_later[p..p + 2],
            &mut a_out[t..t + 4],
            &mut b_out[p..p + 2],
        );
    }
}

/// Cache-blocked dense matmul `C = A · B` (row-major n×n) for the dense
/// compose: `IB`-row × `KB`-column tiles of A are streamed against B rows
/// so each B row loaded into L1 is reused across `IB` output rows, and the
/// inner j-loop is a bounds-check-free lane axpy. For every output entry
/// `C[i][j]` the k-terms still accumulate in ascending global k order —
/// identical to the reference ikj matmul of [`crate::linalg::matmul`], so
/// results match the scalar dense compose bitwise (the reference's
/// zero-skip only ever drops exact-zero contributions).
#[inline]
pub fn matmul_blocked<S: Scalar>(a: &[S], b: &[S], c: &mut [S], n: usize) {
    const IB: usize = 8; // output-row tile
    const KB: usize = 64; // inner-dimension tile (KB·n·8B ≤ 32 KiB at n ≤ 64)
    const W: usize = LANE_BLOCK;
    debug_assert_eq!(a.len(), n * n);
    debug_assert_eq!(b.len(), n * n);
    debug_assert_eq!(c.len(), n * n);
    for v in c.iter_mut() {
        *v = S::zero();
    }
    let jmain = n - n % W;
    let mut i0 = 0;
    while i0 < n {
        let ihi = (i0 + IB).min(n);
        let mut k0 = 0;
        while k0 < n {
            let khi = (k0 + KB).min(n);
            for i in i0..ihi {
                let arow = &a[i * n..(i + 1) * n];
                let crow = &mut c[i * n..(i + 1) * n];
                for k in k0..khi {
                    let aik = arow[k];
                    if aik == S::zero() {
                        continue;
                    }
                    let brow = &b[k * n..(k + 1) * n];
                    let mut j = 0;
                    while j < jmain {
                        let bv = Lanes::<S, W>::load(&brow[j..]);
                        let cv = Lanes::<S, W>::load(&crow[j..]);
                        bv.mul(Lanes::splat(aik)).add(cv).store(&mut crow[j..]);
                        j += W;
                    }
                    for j in jmain..n {
                        crow[j] = crow[j] + aik * brow[j];
                    }
                }
            }
            k0 = khi;
        }
        i0 = ihi;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn lanes_roundtrip_and_ops() {
        let src = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0, 9.0];
        let v = F32x8::load(&src);
        let mut dst = [0.0f32; 9];
        v.store(&mut dst);
        assert_eq!(&dst[..8], &src[..8]);
        assert_eq!(dst[8], 0.0);
        let two = F32x8::splat(2.0);
        let sum = v.add(two);
        let prod = v.mul(two);
        for j in 0..8 {
            assert_eq!(sum.0[j], src[j] + 2.0);
            assert_eq!(prod.0[j], src[j] * 2.0);
        }
        let fma = v.mul_add_separate(two, F32x8::splat(1.0));
        for j in 0..8 {
            assert_eq!(fma.0[j], src[j] * 2.0 + 1.0);
        }
    }

    #[test]
    fn f64x4_ops() {
        let a = F64x4::load(&[1.0, -2.0, 0.5, 4.0]);
        let b = F64x4::splat(3.0);
        let m = a.mul(b);
        assert_eq!(m.0, [3.0, -6.0, 1.5, 12.0]);
    }

    #[test]
    fn matmul_blocked_matches_reference_bitwise() {
        let mut rng = Rng::new(314);
        // shapes straddling both tile sizes and the lane width
        for &n in &[1usize, 2, 3, 7, 8, 9, 16, 33, 64, 65, 100] {
            let mut a = vec![0.0f64; n * n];
            let mut b = vec![0.0f64; n * n];
            rng.fill_normal(&mut a, 1.0);
            rng.fill_normal(&mut b, 1.0);
            let mut want = vec![0.0f64; n * n];
            let mut got = vec![0.0f64; n * n];
            crate::linalg::matmul(&a, &b, &mut want, n);
            matmul_blocked(&a, &b, &mut got, n);
            assert_eq!(want, got, "n={n}");
        }
    }
}
