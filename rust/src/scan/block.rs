//! O((n/k)·k³)-per-element scan kernels for **block-diagonal** affine
//! elements.
//!
//! When every propagator is `A_i = blockdiag(A_i^{(0)}, …, A_i^{(n/k−1)})`
//! with k×k blocks, the eq. (10) monoid closes over packed blocks: compose
//! is n/k independent k×k matmuls and apply n/k independent k×k matvecs.
//! For k ≪ n this removes the O(n³) compose wall of §3.1.1 almost as
//! thoroughly as the diagonal path — O((n/k)·k³) = O(n·k²) per compose —
//! while capturing the per-unit state coupling that the diagonal
//! approximation drops (the ParaRNN observation: LSTM/LEM units carry a
//! coupled 2-tuple, so `Block(2)` is their natural structure).
//!
//! Layout: `a` is `len · (n/k) · k · k` — step i owns `n·k` contiguous
//! elements, block b of step i the row-major k×k tile
//! `a[i·n·k + b·k² .. i·n·k + (b+1)·k²]`. `b`-vectors and states stay
//! packed `[len, n]`; block b of a state vector is the contiguous slice
//! `[b·k, (b+1)·k)`. No n×n temporaries are materialized anywhere — the
//! whole path is O(T·n·k) memory.
//!
//! **Bitwise contract vs the dense kernels**: on a dense embedding of the
//! same block-diagonal elements, every kernel here reproduces the dense
//! kernels of [`super::seq`] / [`super::par`] exactly — the in-block
//! accumulation order matches the dense loops and the skipped off-block
//! terms are exact zeros, so the Block-vs-Dense dispatch never changes
//! results (tests pin this on embedded random blocks).
//!
//! Batched variants follow the `[B, T, …]` layout, active-mask and
//! total-batch-keyed scheduling rules documented in [`crate::scan`].

use super::cr::{par_block_scan_apply_cr_ws, par_block_scan_reverse_cr_ws};
use super::{
    choose_scan_schedule_observed, combine_block, flops_apply_block, flops_combine_block, ScanSchedule,
    ScanWorkspace,
};
use crate::util::scalar::Scalar;

/// `y = A_step · x` over packed k×k tiles, accumulating each row in
/// ascending column order (the dense matvec order restricted to the
/// block). Also the fused-GTMULT building block of the DEER driver's
/// Block(k) path (`crate::deer::newton`).
#[inline]
pub(crate) fn block_matvec<S: Scalar>(a_step: &[S], x: &[S], y: &mut [S], n: usize, k: usize) {
    let nb = n / k;
    for b in 0..nb {
        let tile = &a_step[b * k * k..(b + 1) * k * k];
        let xb = &x[b * k..(b + 1) * k];
        let yb = &mut y[b * k..(b + 1) * k];
        for r in 0..k {
            let row = &tile[r * k..(r + 1) * k];
            let mut acc = S::zero();
            for c in 0..k {
                acc += row[c] * xb[c];
            }
            yb[r] = acc;
        }
    }
}

/// Copy the k×k diagonal blocks of a dense row-major n×n matrix into the
/// packed `[n/k, k, k]` layout — the quasi-DEER block-extraction shared by
/// the DEER forward/backward fallback paths for cells without native
/// packed kernels.
#[inline]
pub(crate) fn extract_blocks<S: Scalar>(dense: &[S], out_blk: &mut [S], n: usize, k: usize) {
    debug_assert_eq!(dense.len(), n * n);
    debug_assert_eq!(out_blk.len(), n * k);
    for bb in 0..n / k {
        for r in 0..k {
            for c in 0..k {
                out_blk[bb * k * k + r * k + c] = dense[(bb * k + r) * n + bb * k + c];
            }
        }
    }
}

/// `y = A_stepᵀ · x` over packed blocks (row-accumulation order of the
/// dense [`crate::linalg::matvec_t`] restricted to each block).
#[inline]
pub(crate) fn block_matvec_t<S: Scalar>(a_step: &[S], x: &[S], y: &mut [S], n: usize, k: usize) {
    let nb = n / k;
    for v in y.iter_mut() {
        *v = S::zero();
    }
    for b in 0..nb {
        let tile = &a_step[b * k * k..(b + 1) * k * k];
        let xb = &x[b * k..(b + 1) * k];
        let yb = &mut y[b * k..(b + 1) * k];
        for r in 0..k {
            let xr = xb[r];
            let row = &tile[r * k..(r + 1) * k];
            for c in 0..k {
                yb[c] += row[c] * xr;
            }
        }
    }
}

/// Sequential `y_i = A_i · y_{i−1} + b_i` with `y_{−1} = y0` over packed
/// k×k blocks.
pub fn seq_block_scan_apply<S: Scalar>(
    a: &[S],
    b: &[S],
    y0: &[S],
    out: &mut [S],
    n: usize,
    k: usize,
    len: usize,
) {
    let bl = n * k;
    debug_assert_eq!(n % k, 0);
    debug_assert_eq!(a.len(), len * bl);
    debug_assert_eq!(b.len(), len * n);
    debug_assert_eq!(out.len(), len * n);
    if len == 0 {
        return;
    }
    {
        let (head, _) = out.split_at_mut(n);
        block_matvec(&a[..bl], y0, head, n, k);
        for j in 0..n {
            head[j] += b[j];
        }
    }
    for i in 1..len {
        let (prev_part, cur_part) = out.split_at_mut(i * n);
        let prev = &prev_part[(i - 1) * n..];
        let cur = &mut cur_part[..n];
        block_matvec(&a[i * bl..(i + 1) * bl], prev, cur, n, k);
        let bi = &b[i * n..(i + 1) * n];
        for j in 0..n {
            cur[j] += bi[j];
        }
    }
}

/// Sequential dual scan `λ_i = g_i + A_{i+1}ᵀ λ_{i+1}` (eq. 7) over packed
/// blocks, `λ_{L−1} = g_{L−1}`. The transpose acts within each k×k tile.
pub fn seq_block_scan_reverse<S: Scalar>(
    a: &[S],
    g: &[S],
    out: &mut [S],
    n: usize,
    k: usize,
    len: usize,
) {
    let bl = n * k;
    debug_assert_eq!(a.len(), len * bl);
    debug_assert_eq!(g.len(), len * n);
    debug_assert_eq!(out.len(), len * n);
    if len == 0 {
        return;
    }
    out[(len - 1) * n..].copy_from_slice(&g[(len - 1) * n..]);
    let mut tmp = vec![S::zero(); n];
    for i in (0..len - 1).rev() {
        let a_next = &a[(i + 1) * bl..(i + 2) * bl];
        let (cur_part, next_part) = out.split_at_mut((i + 1) * n);
        let next = &next_part[..n];
        block_matvec_t(a_next, next, &mut tmp, n, k);
        let cur = &mut cur_part[i * n..];
        let gi = &g[i * n..(i + 1) * n];
        for j in 0..n {
            cur[j] = gi[j] + tmp[j];
        }
    }
}

/// Compose a contiguous range of block-diagonal elements into one `(a, b)`
/// pair: `a = A_{hi−1} ··· A_{lo}` (packed blocks), `b` the matching
/// offset. O(n·k²·(hi−lo)).
#[allow(clippy::too_many_arguments)]
pub fn compose_range_block<S: Scalar>(
    a: &[S],
    b: &[S],
    lo: usize,
    hi: usize,
    a_out: &mut [S],
    b_out: &mut [S],
    n: usize,
    k: usize,
) {
    let bl = n * k;
    let nb = n / k;
    // identity blocks
    for v in a_out.iter_mut() {
        *v = S::zero();
    }
    for bb in 0..nb {
        for r in 0..k {
            a_out[bb * k * k + r * k + r] = S::one();
        }
    }
    for v in b_out.iter_mut() {
        *v = S::zero();
    }
    // (A_i, b_i) ∘ (A_out, b_out) per element, through the shared eq. (10)
    // block combine — one implementation owns the bitwise-sensitive tile
    // compose order.
    let mut tmp_a = vec![S::zero(); bl];
    let mut tmp_b = vec![S::zero(); n];
    for i in lo..hi {
        combine_block(
            &a[i * bl..(i + 1) * bl],
            &b[i * n..(i + 1) * n],
            a_out,
            b_out,
            &mut tmp_a,
            &mut tmp_b,
            n,
            k,
        );
        a_out.copy_from_slice(&tmp_a);
        b_out.copy_from_slice(&tmp_b);
    }
}

/// Parallel block forward scan over `threads` workers (three-phase schedule
/// of [`super::par::par_scan_apply`], every phase O(n·k²) per element).
#[allow(clippy::too_many_arguments)]
pub fn par_block_scan_apply<S: Scalar>(
    a: &[S],
    b: &[S],
    y0: &[S],
    out: &mut [S],
    n: usize,
    k: usize,
    len: usize,
    threads: usize,
) {
    let mut ws = ScanWorkspace::new();
    par_block_scan_apply_ws(a, b, y0, out, n, k, len, threads, &mut ws);
}

/// [`par_block_scan_apply`] with a reusable workspace.
#[allow(clippy::too_many_arguments)]
pub fn par_block_scan_apply_ws<S: Scalar>(
    a: &[S],
    b: &[S],
    y0: &[S],
    out: &mut [S],
    n: usize,
    k: usize,
    len: usize,
    threads: usize,
    ws: &mut ScanWorkspace<S>,
) {
    match choose_scan_schedule_observed(len, threads, flops_combine_block(n, k), flops_apply_block(n, k, 1))
    {
        ScanSchedule::Sequential => {
            seq_block_scan_apply(a, b, y0, out, n, k, len);
            return;
        }
        ScanSchedule::CyclicReduction => {
            par_block_scan_apply_cr_ws(a, b, y0, out, n, k, len, threads, ws);
            return;
        }
        ScanSchedule::Chunked => {}
    }
    let chunks = threads;
    let chunk_len = len.div_ceil(chunks);
    let bl = n * k;
    ws.ensure(chunks * bl, chunks * n, chunks * n);

    // Phase 1: per-chunk composition (packed blocks).
    {
        let comp: Vec<(&mut [S], &mut [S])> = ws.comp_a[..chunks * bl]
            .chunks_mut(bl)
            .zip(ws.comp_b[..chunks * n].chunks_mut(n))
            .collect();
        std::thread::scope(|scope| {
            for (c, (ca, cb)) in comp.into_iter().enumerate() {
                let lo = (c * chunk_len).min(len);
                let hi = ((c + 1) * chunk_len).min(len);
                scope.spawn(move || {
                    compose_range_block(a, b, lo, hi, ca, cb, n, k);
                });
            }
        });
    }

    // Phase 2: sequential carry over chunk entry states (O(n·k·C)).
    let (comp_a, comp_b) = (&ws.comp_a, &ws.comp_b);
    let entries = &mut ws.carry[..chunks * n];
    entries[..n].copy_from_slice(y0);
    for c in 0..chunks - 1 {
        let (head, tail) = entries.split_at_mut((c + 1) * n);
        let prev = &head[c * n..];
        let next = &mut tail[..n];
        block_matvec(&comp_a[c * bl..(c + 1) * bl], prev, next, n, k);
        for j in 0..n {
            next[j] += comp_b[c * n + j];
        }
    }

    // Phase 3: per-chunk apply, in parallel.
    {
        let entries = &ws.carry;
        let mut out_chunks: Vec<&mut [S]> = Vec::with_capacity(chunks);
        let mut rest = out;
        for c in 0..chunks {
            let lo = (c * chunk_len).min(len);
            let hi = ((c + 1) * chunk_len).min(len);
            let (head, tail) = rest.split_at_mut((hi - lo) * n);
            out_chunks.push(head);
            rest = tail;
        }
        std::thread::scope(|scope| {
            for (c, out_c) in out_chunks.into_iter().enumerate() {
                let lo = (c * chunk_len).min(len);
                let hi = ((c + 1) * chunk_len).min(len);
                let entry = &entries[c * n..(c + 1) * n];
                scope.spawn(move || {
                    seq_block_scan_apply(
                        &a[lo * bl..hi * bl],
                        &b[lo * n..hi * n],
                        entry,
                        out_c,
                        n,
                        k,
                        hi - lo,
                    );
                });
            }
        });
    }
}

/// Parallel block dual scan (backward pass, eq. 7 with block-diagonal `A`).
#[allow(clippy::too_many_arguments)]
pub fn par_block_scan_reverse<S: Scalar>(
    a: &[S],
    g: &[S],
    out: &mut [S],
    n: usize,
    k: usize,
    len: usize,
    threads: usize,
) {
    let mut ws = ScanWorkspace::new();
    par_block_scan_reverse_ws(a, g, out, n, k, len, threads, &mut ws);
}

/// [`par_block_scan_reverse`] with a reusable workspace.
#[allow(clippy::too_many_arguments)]
pub fn par_block_scan_reverse_ws<S: Scalar>(
    a: &[S],
    g: &[S],
    out: &mut [S],
    n: usize,
    k: usize,
    len: usize,
    threads: usize,
    ws: &mut ScanWorkspace<S>,
) {
    match choose_scan_schedule_observed(len, threads, flops_combine_block(n, k), flops_apply_block(n, k, 1))
    {
        ScanSchedule::Sequential => {
            seq_block_scan_reverse(a, g, out, n, k, len);
            return;
        }
        ScanSchedule::CyclicReduction => {
            par_block_scan_reverse_cr_ws(a, g, out, n, k, len, threads, ws);
            return;
        }
        ScanSchedule::Chunked => {}
    }
    let chunks = threads;
    let chunk_len = len.div_ceil(chunks);
    let bl = n * k;
    let nb = n / k;
    ws.ensure(chunks * bl, chunks * n, chunks * n);

    // Phase 1: per-chunk reverse composition. For chunk [lo, hi):
    // λ_{lo} = M_c · λ_{hi} + v_c with M_c packed blocks, built
    // right-to-left: new M = A_{i+1}ᵀ · M, new v = A_{i+1}ᵀ v + g_i.
    {
        let comp: Vec<(&mut [S], &mut [S])> = ws.comp_a[..chunks * bl]
            .chunks_mut(bl)
            .zip(ws.comp_b[..chunks * n].chunks_mut(n))
            .collect();
        std::thread::scope(|scope| {
            for (c, (cm, cv)) in comp.into_iter().enumerate() {
                let lo = (c * chunk_len).min(len);
                let hi = ((c + 1) * chunk_len).min(len);
                scope.spawn(move || {
                    // identity blocks to start (λ_hi passes through)
                    for v in cm.iter_mut() {
                        *v = S::zero();
                    }
                    for bb in 0..nb {
                        for r in 0..k {
                            cm[bb * k * k + r * k + r] = S::one();
                        }
                    }
                    for v in cv.iter_mut() {
                        *v = S::zero();
                    }
                    let mut tm = vec![S::zero(); k * k];
                    let mut tv = vec![S::zero(); n];
                    for i in (lo..hi).rev() {
                        if i + 1 < len {
                            let an = &a[(i + 1) * bl..(i + 2) * bl];
                            for bb in 0..nb {
                                let tile = &an[bb * k * k..(bb + 1) * k * k];
                                let mblk = &mut cm[bb * k * k..(bb + 1) * k * k];
                                // new M_blk = tileᵀ · M_blk (the dense
                                // transposed-multiply order per block)
                                for r in 0..k {
                                    for ccol in 0..k {
                                        let mut acc = S::zero();
                                        for kk in 0..k {
                                            acc += tile[kk * k + r] * mblk[kk * k + ccol];
                                        }
                                        tm[r * k + ccol] = acc;
                                    }
                                }
                                mblk.copy_from_slice(&tm);
                            }
                            block_matvec_t(an, cv, &mut tv, n, k);
                            for j in 0..n {
                                cv[j] = tv[j] + g[i * n + j];
                            }
                        } else {
                            // last element of the whole sequence: λ = g only
                            for v in cm.iter_mut() {
                                *v = S::zero();
                            }
                            cv.copy_from_slice(&g[i * n..(i + 1) * n]);
                        }
                    }
                });
            }
        });
    }

    // Phase 2: carry λ at chunk boundaries, right to left.
    let (comp_m, comp_v) = (&ws.comp_a, &ws.comp_b);
    let exits = &mut ws.carry[..chunks * n];
    for v in exits[(chunks - 1) * n..].iter_mut() {
        *v = S::zero();
    }
    for c in (1..chunks).rev() {
        let (head, tail) = exits.split_at_mut(c * n);
        let cur = &tail[..n];
        let prev = &mut head[(c - 1) * n..];
        block_matvec(&comp_m[c * bl..(c + 1) * bl], cur, prev, n, k);
        for j in 0..n {
            prev[j] += comp_v[c * n + j];
        }
    }

    // Phase 3: per-chunk reverse apply.
    {
        let exits = &ws.carry;
        let mut out_chunks: Vec<&mut [S]> = Vec::with_capacity(chunks);
        let mut rest = out;
        for c in 0..chunks {
            let lo = (c * chunk_len).min(len);
            let hi = ((c + 1) * chunk_len).min(len);
            let (head, tail) = rest.split_at_mut((hi - lo) * n);
            out_chunks.push(head);
            rest = tail;
        }
        std::thread::scope(|scope| {
            for (c, out_c) in out_chunks.into_iter().enumerate() {
                let lo = (c * chunk_len).min(len);
                let hi = ((c + 1) * chunk_len).min(len);
                let exit = &exits[c * n..(c + 1) * n];
                scope.spawn(move || {
                    let mut next = exit.to_vec();
                    let mut tmp = vec![S::zero(); n];
                    for i in (lo..hi).rev() {
                        let li = i - lo;
                        if i + 1 < len {
                            let an = &a[(i + 1) * bl..(i + 2) * bl];
                            block_matvec_t(an, &next, &mut tmp, n, k);
                            for j in 0..n {
                                out_c[li * n + j] = g[i * n + j] + tmp[j];
                            }
                        } else {
                            out_c[li * n..(li + 1) * n].copy_from_slice(&g[i * n..(i + 1) * n]);
                        }
                        next.copy_from_slice(&out_c[li * n..(li + 1) * n]);
                    }
                });
            }
        });
    }
}

/// Fused batched block forward scan over B independent sequences in the
/// `[B, T, n·k]` / `[B, T, n]` layout (scheduling + masking rules of
/// [`crate::scan`]: whole sequences per worker at B ≥ threads, fixed
/// intra-sequence split below, everything keyed on the total batch size).
#[allow(clippy::too_many_arguments)]
pub fn par_block_scan_apply_batch_ws<S: Scalar>(
    a: &[S],
    b: &[S],
    y0s: &[S],
    out: &mut [S],
    n: usize,
    k: usize,
    t_len: usize,
    batch: usize,
    active: Option<&[bool]>,
    threads: usize,
    ws: &mut ScanWorkspace<S>,
) {
    let bl = n * k;
    debug_assert_eq!(a.len(), batch * t_len * bl);
    debug_assert_eq!(b.len(), batch * t_len * n);
    debug_assert_eq!(y0s.len(), batch * n);
    debug_assert_eq!(out.len(), batch * t_len * n);
    let idx = crate::scan::active_indices(batch, active);
    if idx.is_empty() || t_len == 0 {
        return;
    }
    let sa = t_len * bl;
    let sn = t_len * n;
    if batch == 1 {
        par_block_scan_apply_ws(a, b, y0s, out, n, k, t_len, threads, ws);
        return;
    }
    let mut slabs: Vec<Option<&mut [S]>> = out.chunks_mut(sn).map(Some).collect();
    if threads <= 1 {
        for &s in &idx {
            let o = slabs[s].take().unwrap();
            seq_block_scan_apply(
                &a[s * sa..(s + 1) * sa],
                &b[s * sn..(s + 1) * sn],
                &y0s[s * n..(s + 1) * n],
                o,
                n,
                k,
                t_len,
            );
        }
    } else if batch >= threads {
        let workers = threads.min(idx.len());
        let mut buckets: Vec<Vec<(usize, &mut [S])>> = (0..workers).map(|_| Vec::new()).collect();
        for (kk, &s) in idx.iter().enumerate() {
            buckets[kk % workers].push((s, slabs[s].take().unwrap()));
        }
        std::thread::scope(|scope| {
            for bucket in buckets {
                scope.spawn(move || {
                    for (s, o) in bucket {
                        seq_block_scan_apply(
                            &a[s * sa..(s + 1) * sa],
                            &b[s * sn..(s + 1) * sn],
                            &y0s[s * n..(s + 1) * n],
                            o,
                            n,
                            k,
                            t_len,
                        );
                    }
                });
            }
        });
    } else {
        // 1 < B < threads: fixed intra-sequence split (constant divisor B
        // keeps the decomposition masking-invariant)
        let cps = (threads / batch).max(2);
        std::thread::scope(|scope| {
            for &s in &idx {
                let o = slabs[s].take().unwrap();
                let a_s = &a[s * sa..(s + 1) * sa];
                let b_s = &b[s * sn..(s + 1) * sn];
                let y0_s = &y0s[s * n..(s + 1) * n];
                scope.spawn(move || {
                    let mut local = ScanWorkspace::new();
                    par_block_scan_apply_ws(a_s, b_s, y0_s, o, n, k, t_len, cps, &mut local);
                });
            }
        });
    }
}

/// Fused batched block dual scan (`[B, T, …]` layout; same scheduling and
/// masking rules as [`par_block_scan_apply_batch_ws`]).
#[allow(clippy::too_many_arguments)]
pub fn par_block_scan_reverse_batch_ws<S: Scalar>(
    a: &[S],
    g: &[S],
    out: &mut [S],
    n: usize,
    k: usize,
    t_len: usize,
    batch: usize,
    active: Option<&[bool]>,
    threads: usize,
    ws: &mut ScanWorkspace<S>,
) {
    let bl = n * k;
    debug_assert_eq!(a.len(), batch * t_len * bl);
    debug_assert_eq!(g.len(), batch * t_len * n);
    debug_assert_eq!(out.len(), batch * t_len * n);
    let idx = crate::scan::active_indices(batch, active);
    if idx.is_empty() || t_len == 0 {
        return;
    }
    let sa = t_len * bl;
    let sn = t_len * n;
    if batch == 1 {
        par_block_scan_reverse_ws(a, g, out, n, k, t_len, threads, ws);
        return;
    }
    let mut slabs: Vec<Option<&mut [S]>> = out.chunks_mut(sn).map(Some).collect();
    if threads <= 1 {
        for &s in &idx {
            let o = slabs[s].take().unwrap();
            seq_block_scan_reverse(
                &a[s * sa..(s + 1) * sa],
                &g[s * sn..(s + 1) * sn],
                o,
                n,
                k,
                t_len,
            );
        }
    } else if batch >= threads {
        let workers = threads.min(idx.len());
        let mut buckets: Vec<Vec<(usize, &mut [S])>> = (0..workers).map(|_| Vec::new()).collect();
        for (kk, &s) in idx.iter().enumerate() {
            buckets[kk % workers].push((s, slabs[s].take().unwrap()));
        }
        std::thread::scope(|scope| {
            for bucket in buckets {
                scope.spawn(move || {
                    for (s, o) in bucket {
                        seq_block_scan_reverse(
                            &a[s * sa..(s + 1) * sa],
                            &g[s * sn..(s + 1) * sn],
                            o,
                            n,
                            k,
                            t_len,
                        );
                    }
                });
            }
        });
    } else {
        let cps = (threads / batch).max(2);
        std::thread::scope(|scope| {
            for &s in &idx {
                let o = slabs[s].take().unwrap();
                let a_s = &a[s * sa..(s + 1) * sa];
                let g_s = &g[s * sn..(s + 1) * sn];
                scope.spawn(move || {
                    let mut local = ScanWorkspace::new();
                    par_block_scan_reverse_ws(a_s, g_s, o, n, k, t_len, cps, &mut local);
                });
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scan::diag::{seq_diag_scan_apply, seq_diag_scan_reverse};
    use crate::scan::seq::{seq_scan_apply, seq_scan_reverse};
    use crate::util::rng::Rng;

    fn random_block(
        n: usize,
        k: usize,
        len: usize,
        seed: u64,
    ) -> (Vec<f64>, Vec<f64>, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let mut a = vec![0.0; len * n * k];
        let mut b = vec![0.0; len * n];
        let mut y0 = vec![0.0; n];
        rng.fill_normal(&mut a, 0.45);
        rng.fill_normal(&mut b, 1.0);
        rng.fill_normal(&mut y0, 1.0);
        (a, b, y0)
    }

    /// Embed packed blocks into dense n×n matrices.
    fn embed_dense(a: &[f64], n: usize, k: usize, len: usize) -> Vec<f64> {
        let nb = n / k;
        let bl = n * k;
        let mut dense = vec![0.0; len * n * n];
        for i in 0..len {
            for bb in 0..nb {
                for r in 0..k {
                    for c in 0..k {
                        dense[i * n * n + (bb * k + r) * n + bb * k + c] =
                            a[i * bl + bb * k * k + r * k + c];
                    }
                }
            }
        }
        dense
    }

    /// The block forward scan must equal the dense scan on the embedded
    /// elements **bitwise** — the Block-vs-Dense dispatch contract.
    #[test]
    fn block_forward_matches_dense_scan_bitwise() {
        for &(n, k, len) in &[(2usize, 2usize, 40usize), (6, 2, 111), (8, 4, 64), (9, 3, 57)] {
            let (a, b, y0) = random_block(n, k, len, 7 + (n * k) as u64);
            let dense = embed_dense(&a, n, k, len);
            let mut out_dense = vec![0.0; len * n];
            let mut out_block = vec![0.0; len * n];
            seq_scan_apply(&dense, &b, &y0, &mut out_dense, n, len);
            seq_block_scan_apply(&a, &b, &y0, &mut out_block, n, k, len);
            assert_eq!(out_dense, out_block, "n={n} k={k} len={len}");
        }
    }

    #[test]
    fn block_reverse_matches_dense_scan_bitwise() {
        for &(n, k, len) in &[(2usize, 2usize, 33usize), (8, 2, 90), (6, 3, 57)] {
            let (a, g, _) = random_block(n, k, len, 31 + (n * k) as u64);
            let dense = embed_dense(&a, n, k, len);
            let mut out_dense = vec![0.0; len * n];
            let mut out_block = vec![0.0; len * n];
            seq_scan_reverse(&dense, &g, &mut out_dense, n, len);
            seq_block_scan_reverse(&a, &g, &mut out_block, n, k, len);
            assert_eq!(out_dense, out_block, "n={n} k={k} len={len}");
        }
    }

    /// k = 1 degenerates to the packed diagonal kernels exactly.
    #[test]
    fn block_k1_matches_diag() {
        let (n, len) = (5usize, 80usize);
        let (a, b, y0) = random_block(n, 1, len, 99);
        let mut out_diag = vec![0.0; len * n];
        let mut out_block = vec![0.0; len * n];
        seq_diag_scan_apply(&a, &b, &y0, &mut out_diag, n, len);
        seq_block_scan_apply(&a, &b, &y0, &mut out_block, n, 1, len);
        for (x, y) in out_diag.iter().zip(out_block.iter()) {
            assert!((x - y).abs() < 1e-12);
        }
        let mut rev_diag = vec![0.0; len * n];
        let mut rev_block = vec![0.0; len * n];
        seq_diag_scan_reverse(&a, &b, &mut rev_diag, n, len);
        seq_block_scan_reverse(&a, &b, &mut rev_block, n, 1, len);
        for (x, y) in rev_diag.iter().zip(rev_block.iter()) {
            assert!((x - y).abs() < 1e-12);
        }
    }

    #[test]
    fn par_matches_seq_forward_all_thread_counts() {
        for &threads in &[1usize, 2, 4, 8] {
            for &(n, k, len) in &[(4usize, 2usize, 257usize), (6, 3, 100), (16, 2, 1000)] {
                let (a, b, y0) = random_block(n, k, len, threads as u64 * 91 + n as u64);
                let mut out_s = vec![0.0; len * n];
                let mut out_p = vec![0.0; len * n];
                seq_block_scan_apply(&a, &b, &y0, &mut out_s, n, k, len);
                par_block_scan_apply(&a, &b, &y0, &mut out_p, n, k, len, threads);
                for (i, (x, y)) in out_s.iter().zip(out_p.iter()).enumerate() {
                    assert!(
                        (x - y).abs() < 1e-9,
                        "t={threads} n={n} k={k} len={len} i={i}: {x} vs {y}"
                    );
                }
            }
        }
    }

    #[test]
    fn par_matches_seq_reverse_all_thread_counts() {
        for &threads in &[1usize, 2, 4, 8] {
            for &(n, k, len) in &[(4usize, 2usize, 300usize), (6, 2, 65), (8, 4, 513)] {
                let (a, g, _) = random_block(n, k, len, threads as u64 * 17 + len as u64);
                let mut out_s = vec![0.0; len * n];
                let mut out_p = vec![0.0; len * n];
                seq_block_scan_reverse(&a, &g, &mut out_s, n, k, len);
                par_block_scan_reverse(&a, &g, &mut out_p, n, k, len, threads);
                for (i, (x, y)) in out_s.iter().zip(out_p.iter()).enumerate() {
                    assert!(
                        (x - y).abs() < 1e-9,
                        "t={threads} n={n} k={k} len={len} i={i}: {x} vs {y}"
                    );
                }
            }
        }
    }

    #[test]
    fn compose_range_block_equals_endpoint() {
        let (n, k, len) = (6, 2, 17);
        let (a, b, y0) = random_block(n, k, len, 4);
        let mut out = vec![0.0; len * n];
        seq_block_scan_apply(&a, &b, &y0, &mut out, n, k, len);
        let mut ca = vec![0.0; n * k];
        let mut cb = vec![0.0; n];
        compose_range_block(&a, &b, 0, len, &mut ca, &mut cb, n, k);
        let mut y_end = vec![0.0; n];
        block_matvec(&ca, &y0, &mut y_end, n, k);
        for j in 0..n {
            let v = y_end[j] + cb[j];
            assert!((v - out[(len - 1) * n + j]).abs() < 1e-10, "j={j}");
        }
    }

    /// One fused batched block call == B independent sequential scans across
    /// scheduling regimes, and the active mask freezes sequences in place.
    #[test]
    fn batch_block_forward_matches_per_sequence_and_masks() {
        for &(n, k, t_len, batch, threads) in &[
            (4usize, 2usize, 200usize, 6usize, 2usize),
            (6, 3, 150, 2, 8),
            (8, 2, 64, 4, 1),
        ] {
            let mut rng = Rng::new(5000 + (n * batch * threads) as u64);
            let sa = t_len * n * k;
            let sn = t_len * n;
            let mut a = vec![0.0f64; batch * sa];
            let mut b = vec![0.0f64; batch * sn];
            let mut y0s = vec![0.0f64; batch * n];
            rng.fill_normal(&mut a, 0.45);
            rng.fill_normal(&mut b, 1.0);
            rng.fill_normal(&mut y0s, 1.0);

            let sentinel = -555.0f64;
            let mut active = vec![true; batch];
            active[batch - 1] = false;
            let mut got = vec![sentinel; batch * sn];
            let mut ws = ScanWorkspace::new();
            par_block_scan_apply_batch_ws(
                &a, &b, &y0s, &mut got, n, k, t_len, batch, Some(&active), threads, &mut ws,
            );
            for s in 0..batch {
                let slab = &got[s * sn..(s + 1) * sn];
                if active[s] {
                    let mut want = vec![0.0f64; sn];
                    seq_block_scan_apply(
                        &a[s * sa..(s + 1) * sa],
                        &b[s * sn..(s + 1) * sn],
                        &y0s[s * n..(s + 1) * n],
                        &mut want,
                        n,
                        k,
                        t_len,
                    );
                    for (x, y) in want.iter().zip(slab.iter()) {
                        assert!((x - y).abs() < 1e-9, "B={batch} thr={threads} seq {s}");
                    }
                } else {
                    assert!(slab.iter().all(|&v| v == sentinel), "masked seq {s} written");
                }
            }
        }
    }

    #[test]
    fn batch_block_reverse_matches_per_sequence() {
        for &(n, k, t_len, batch, threads) in &[
            (4usize, 2usize, 180usize, 5usize, 2usize),
            (6, 2, 300, 3, 8),
            (8, 4, 90, 6, 1),
        ] {
            let mut rng = Rng::new(6000 + (n * batch * threads) as u64);
            let sa = t_len * n * k;
            let sn = t_len * n;
            let mut a = vec![0.0f64; batch * sa];
            let mut g = vec![0.0f64; batch * sn];
            rng.fill_normal(&mut a, 0.45);
            rng.fill_normal(&mut g, 1.0);

            let mut want = vec![0.0f64; batch * sn];
            for s in 0..batch {
                seq_block_scan_reverse(
                    &a[s * sa..(s + 1) * sa],
                    &g[s * sn..(s + 1) * sn],
                    &mut want[s * sn..(s + 1) * sn],
                    n,
                    k,
                    t_len,
                );
            }
            let mut got = vec![0.0f64; batch * sn];
            let mut ws = ScanWorkspace::new();
            par_block_scan_reverse_batch_ws(
                &a, &g, &mut got, n, k, t_len, batch, None, threads, &mut ws,
            );
            for (x, y) in want.iter().zip(got.iter()) {
                assert!((x - y).abs() < 1e-9, "B={batch} thr={threads}: {x} vs {y}");
            }
        }
    }

    #[test]
    fn workspace_reuse_across_shapes() {
        let mut ws = ScanWorkspace::new();
        for &(n, k, len, threads) in
            &[(8usize, 2usize, 400usize, 8usize), (4, 2, 64, 4), (6, 3, 300, 2)]
        {
            let (a, b, y0) = random_block(n, k, len, 7000 + len as u64);
            let mut out_s = vec![0.0; len * n];
            let mut out_p = vec![0.0; len * n];
            seq_block_scan_apply(&a, &b, &y0, &mut out_s, n, k, len);
            par_block_scan_apply_ws(&a, &b, &y0, &mut out_p, n, k, len, threads, &mut ws);
            for (x, y) in out_s.iter().zip(out_p.iter()) {
                assert!((x - y).abs() < 1e-9);
            }
        }
    }
}
