//! Kalman/information-filter kernels for the damped (ELK) INVLIN solve.
//!
//! # The damped linear system is still an associative scan
//!
//! ELK (Gonzalez et al., "Towards Scalable and Stable Parallelization of
//! Nonlinear RNNs") stabilizes the DEER Newton step with Levenberg–Marquardt
//! damping. With trajectory guess `z = y^{(k)}` and per-step Jacobians
//! `A_i = J_i`, the damped Newton system in delta form is the
//! lower-bidiagonal
//!
//! ```text
//! (1 + λ) Δ_i − A_i Δ_{i−1} = −r_i,      r_i = z_i − f(z_{i−1}, x_i)
//! ```
//!
//! Substituting `ŷ = z + Δ` and the DEER rhs `b_i = f_i − A_i z_{i−1}` turns
//! this into a *state-form* affine recurrence (derivation: expand
//! `(1+λ)ŷ_i = (1+λ)z_i + A_i Δ_{i−1} − r_i` and cancel `z_i` terms):
//!
//! ```text
//! ŷ_i = s · (A_i ŷ_{i−1} + b_i + λ z_i),      s = 1 / (1 + λ)
//! ```
//!
//! This is exactly the steady-state **information filter** update of a
//! linear-Gaussian smoothing pass: the prediction `A_i ŷ_{i−1} + b_i`
//! (process model, unit precision) is blended with the observation `z_i`
//! (precision λ) and the posterior mean is the precision-weighted average
//! `(prediction + λ·z_i) / (1 + λ)`. λ = 0 trusts the model fully and
//! recovers the undamped DEER scan; λ → ∞ pins `ŷ → z` (zero Newton step).
//!
//! Crucially the damped element `(A_i, b_i, λ)` maps to a *scaled* element
//! of the SAME affine monoid the dense/diag/block scans already compose:
//!
//! ```text
//! (Ã_i, b̃_i) = (s·A_i,  s·(b_i + λ z_i))
//! ```
//!
//! so every kernel here is the corresponding plain scan with the `s` gain
//! fused on the fly — no scaled copy of the Jacobian slab is ever
//! materialized (the driver re-uses `a` across accept/reject retries and
//! the backward pass). Since `|s| ≤ 1`, composing scaled elements is at
//! least as numerically tame as the undamped compose: damping strictly
//! shrinks the propagator products that overflow on divergent solves.
//!
//! The reverse (dual) kernels solve the transpose of the damped system,
//! used by the backward pass when it reuses the last accepted forward λ:
//!
//! ```text
//! λ_i = s · (g_i + A_{i+1}ᵀ λ_{i+1})        (beyond-end A treated as 0)
//! ```
//!
//! # Dispatch contract
//!
//! All entry points take a [`JacobianStructure`] and accept the same packed
//! Jacobian layouts as the dense/diag/block kernels. A row with `λ == 0`
//! routes to the *plain* kernel of its structure, so undamped results are
//! **bitwise identical** to the existing solve (the fused `s`-scaling never
//! executes). Batched variants take one λ per sequence plus the usual
//! active mask, and key their scheduling on the TOTAL batch size so
//! accumulation order is independent of masking state — the same
//! bit-reproducibility contract as [`crate::scan::par`].
//!
//! Full covariance-propagating Kalman smoothing (per-step uncertainty
//! output) is out of scope here and recorded in ROADMAP as a follow-up;
//! the solver only needs the MAP trajectory, which is what these kernels
//! produce.

use super::block::{block_matvec, block_matvec_t};
use super::{
    active_indices, choose_scan_schedule_observed, combine, combine_block, combine_diag,
    flops_apply_kalman, flops_apply_kalman_block, flops_apply_kalman_diag, flops_combine_kalman,
    flops_combine_kalman_block, flops_combine_kalman_diag, par_block_scan_apply_ws,
    par_block_scan_reverse_ws, par_diag_scan_apply_ws, par_diag_scan_reverse_ws, par_scan_apply_ws,
    par_scan_reverse_ws, seq_block_scan_apply, seq_block_scan_reverse, seq_diag_scan_apply,
    seq_diag_scan_reverse, seq_scan_apply, seq_scan_reverse, ScanSchedule, ScanWorkspace,
};
use crate::cells::JacobianStructure;
use crate::linalg::{eye_into, matvec, matvec_t};
use crate::util::scalar::Scalar;

/// Per-element damped compose cost for the structure at hand (the chooser
/// input — see [`super::choose_scan_schedule`]).
fn kalman_combine_flops(st: JacobianStructure, n: usize) -> u64 {
    match st {
        JacobianStructure::Dense => flops_combine_kalman(n),
        JacobianStructure::Diagonal => flops_combine_kalman_diag(n),
        JacobianStructure::Block { k } => flops_combine_kalman_block(n, k),
    }
}

/// Per-element damped apply cost for the structure at hand.
fn kalman_apply_flops(st: JacobianStructure, n: usize) -> u64 {
    match st {
        JacobianStructure::Dense => flops_apply_kalman(n, 1),
        JacobianStructure::Diagonal => flops_apply_kalman_diag(n, 1),
        JacobianStructure::Block { k } => flops_apply_kalman_block(n, k, 1),
    }
}

/// Information-filter gain `s = 1 / (1 + λ)`.
#[inline]
pub fn damp_gain<S: Scalar>(lambda: S) -> S {
    S::one() / (S::one() + lambda)
}

/// `y = A_i · x` for one packed per-step Jacobian of any structure.
#[inline]
pub(crate) fn apply_a<S: Scalar>(st: JacobianStructure, a_i: &[S], x: &[S], y: &mut [S], n: usize) {
    match st {
        JacobianStructure::Dense => matvec(a_i, x, y),
        JacobianStructure::Diagonal => {
            for j in 0..n {
                y[j] = a_i[j] * x[j];
            }
        }
        JacobianStructure::Block { k } => block_matvec(a_i, x, y, n, k),
    }
}

/// `y = A_iᵀ · x` for one packed per-step Jacobian of any structure.
#[inline]
pub(crate) fn apply_a_t<S: Scalar>(
    st: JacobianStructure,
    a_i: &[S],
    x: &[S],
    y: &mut [S],
    n: usize,
) {
    match st {
        JacobianStructure::Dense => matvec_t(a_i, x, y),
        JacobianStructure::Diagonal => {
            for j in 0..n {
                y[j] = a_i[j] * x[j];
            }
        }
        JacobianStructure::Block { k } => block_matvec_t(a_i, x, y, n, k),
    }
}

/// Identity element of the structure's affine monoid into `a_out`.
fn identity_into<S: Scalar>(st: JacobianStructure, a_out: &mut [S], n: usize) {
    match st {
        JacobianStructure::Dense => eye_into(a_out, n),
        JacobianStructure::Diagonal => {
            for v in a_out.iter_mut() {
                *v = S::one();
            }
        }
        JacobianStructure::Block { k } => {
            for v in a_out.iter_mut() {
                *v = S::zero();
            }
            for bb in 0..n / k {
                for r in 0..k {
                    a_out[bb * k * k + r * k + r] = S::one();
                }
            }
        }
    }
}

/// `acc ← el ∘ acc` through the structure's combine, staging in `tmp_*`.
#[allow(clippy::too_many_arguments)]
fn compose_into<S: Scalar>(
    st: JacobianStructure,
    el_a: &[S],
    el_b: &[S],
    acc_a: &mut [S],
    acc_b: &mut [S],
    tmp_a: &mut [S],
    tmp_b: &mut [S],
    n: usize,
) {
    match st {
        JacobianStructure::Dense => combine(el_a, el_b, acc_a, acc_b, tmp_a, tmp_b, n),
        JacobianStructure::Diagonal => combine_diag(el_a, el_b, acc_a, acc_b, tmp_a, tmp_b, n),
        JacobianStructure::Block { k } => {
            combine_block(el_a, el_b, acc_a, acc_b, tmp_a, tmp_b, n, k)
        }
    }
    acc_a.copy_from_slice(tmp_a);
    acc_b.copy_from_slice(tmp_b);
}

/// Sequential damped scan `ŷ_i = s·(A_i ŷ_{i−1} + b_i + λ z_i)` with
/// `ŷ_{−1} = y0`. `z` is the anchor trajectory (the current Newton guess);
/// at `λ = 0` this routes to the plain kernel of `structure` and is bitwise
/// identical to the undamped solve.
#[allow(clippy::too_many_arguments)]
pub fn seq_kalman_scan_apply<S: Scalar>(
    a: &[S],
    b: &[S],
    z: &[S],
    y0: &[S],
    out: &mut [S],
    n: usize,
    structure: JacobianStructure,
    len: usize,
    lambda: S,
) {
    let jl = structure.jac_len(n);
    debug_assert_eq!(a.len(), len * jl);
    debug_assert_eq!(b.len(), len * n);
    debug_assert_eq!(z.len(), len * n);
    debug_assert_eq!(out.len(), len * n);
    if len == 0 {
        return;
    }
    if lambda == S::zero() {
        match structure {
            JacobianStructure::Dense => seq_scan_apply(a, b, y0, out, n, len),
            JacobianStructure::Diagonal => seq_diag_scan_apply(a, b, y0, out, n, len),
            JacobianStructure::Block { k } => seq_block_scan_apply(a, b, y0, out, n, k, len),
        }
        return;
    }
    let s = damp_gain(lambda);
    {
        let head = &mut out[..n];
        apply_a(structure, &a[..jl], y0, head, n);
        for j in 0..n {
            head[j] = s * (head[j] + b[j] + lambda * z[j]);
        }
    }
    for i in 1..len {
        let (prev_part, cur_part) = out.split_at_mut(i * n);
        let prev = &prev_part[(i - 1) * n..];
        let cur = &mut cur_part[..n];
        apply_a(structure, &a[i * jl..(i + 1) * jl], prev, cur, n);
        let bi = &b[i * n..(i + 1) * n];
        let zi = &z[i * n..(i + 1) * n];
        for j in 0..n {
            cur[j] = s * (cur[j] + bi[j] + lambda * zi[j]);
        }
    }
}

/// Reverse damped replay over `[lo, hi)` of a length-`len` sequence:
/// `λ_i = s·(g_i + A_{i+1}ᵀ λ_{i+1})`, taking `λ_hi` from `exit` when the
/// chunk does not end the sequence (beyond-end `A` is 0, so the final
/// element is `s·g`). `out_chunk` holds `(hi − lo)·n`.
#[allow(clippy::too_many_arguments)]
fn seq_kalman_rev_range<S: Scalar>(
    a: &[S],
    g: &[S],
    lo: usize,
    hi: usize,
    len: usize,
    exit: &[S],
    out_chunk: &mut [S],
    n: usize,
    structure: JacobianStructure,
    s: S,
) {
    let jl = structure.jac_len(n);
    let mut tv = vec![S::zero(); n];
    for i in (lo..hi).rev() {
        let idx = i - lo;
        if i + 1 >= len {
            for j in 0..n {
                out_chunk[idx * n + j] = s * g[i * n + j];
            }
            continue;
        }
        let a_next = &a[(i + 1) * jl..(i + 2) * jl];
        if i + 1 < hi {
            let (cur_part, next_part) = out_chunk.split_at_mut((idx + 1) * n);
            apply_a_t(structure, a_next, &next_part[..n], &mut tv, n);
            let cur = &mut cur_part[idx * n..];
            for j in 0..n {
                cur[j] = s * (tv[j] + g[i * n + j]);
            }
        } else {
            apply_a_t(structure, a_next, exit, &mut tv, n);
            for j in 0..n {
                out_chunk[idx * n + j] = s * (tv[j] + g[i * n + j]);
            }
        }
    }
}

/// Sequential damped reverse (dual) scan `λ_i = s·(g_i + A_{i+1}ᵀ λ_{i+1})`.
/// At `λ = 0` this routes to the plain reverse kernel of `structure`.
#[allow(clippy::too_many_arguments)]
pub fn seq_kalman_scan_reverse<S: Scalar>(
    a: &[S],
    g: &[S],
    out: &mut [S],
    n: usize,
    structure: JacobianStructure,
    len: usize,
    lambda: S,
) {
    let jl = structure.jac_len(n);
    debug_assert_eq!(a.len(), len * jl);
    debug_assert_eq!(g.len(), len * n);
    debug_assert_eq!(out.len(), len * n);
    if len == 0 {
        return;
    }
    if lambda == S::zero() {
        match structure {
            JacobianStructure::Dense => seq_scan_reverse(a, g, out, n, len),
            JacobianStructure::Diagonal => seq_diag_scan_reverse(a, g, out, n, len),
            JacobianStructure::Block { k } => seq_block_scan_reverse(a, g, out, n, k, len),
        }
        return;
    }
    seq_kalman_rev_range(a, g, 0, len, len, &[], out, n, structure, damp_gain(lambda));
}

/// Compose the scaled elements `(s·A_i, s·(b_i + λ z_i))` over `[lo, hi)`
/// into one `(a_out, b_out)` element — phase 1 of the chunked damped scan.
#[allow(clippy::too_many_arguments)]
fn compose_range_kalman<S: Scalar>(
    a: &[S],
    b: &[S],
    z: &[S],
    lo: usize,
    hi: usize,
    lambda: S,
    a_out: &mut [S],
    b_out: &mut [S],
    n: usize,
    structure: JacobianStructure,
) {
    let jl = structure.jac_len(n);
    let s = damp_gain(lambda);
    identity_into(structure, a_out, n);
    for v in b_out.iter_mut() {
        *v = S::zero();
    }
    let mut el_a = vec![S::zero(); jl];
    let mut el_b = vec![S::zero(); n];
    let mut tmp_a = vec![S::zero(); jl];
    let mut tmp_b = vec![S::zero(); n];
    for i in lo..hi {
        for q in 0..jl {
            el_a[q] = s * a[i * jl + q];
        }
        for j in 0..n {
            el_b[j] = s * (b[i * n + j] + lambda * z[i * n + j]);
        }
        compose_into(structure, &el_a, &el_b, a_out, b_out, &mut tmp_a, &mut tmp_b, n);
    }
}

/// Chunked three-phase damped scan over one sequence: compose scaled
/// elements per chunk, sequential carry, per-chunk damped replay. Falls
/// back to [`seq_kalman_scan_apply`] when too short or single-threaded; at
/// `λ = 0` it delegates to the plain kernel family and is bitwise equal to
/// the undamped solve.
#[allow(clippy::too_many_arguments)]
pub fn par_kalman_scan_apply_ws<S: Scalar>(
    a: &[S],
    b: &[S],
    z: &[S],
    y0: &[S],
    out: &mut [S],
    n: usize,
    structure: JacobianStructure,
    len: usize,
    lambda: S,
    threads: usize,
    ws: &mut ScanWorkspace<S>,
) {
    if lambda == S::zero() {
        match structure {
            JacobianStructure::Dense => par_scan_apply_ws(a, b, y0, out, n, len, threads, ws),
            JacobianStructure::Diagonal => {
                par_diag_scan_apply_ws(a, b, y0, out, n, len, threads, ws)
            }
            JacobianStructure::Block { k } => {
                par_block_scan_apply_ws(a, b, y0, out, n, k, len, threads, ws)
            }
        }
        return;
    }
    match choose_scan_schedule_observed(len, threads, kalman_combine_flops(structure, n), kalman_apply_flops(structure, n)) {
        ScanSchedule::Sequential => {
            seq_kalman_scan_apply(a, b, z, y0, out, n, structure, len, lambda);
            return;
        }
        ScanSchedule::CyclicReduction => {
            super::cr::par_kalman_scan_apply_cr_ws(
                a, b, z, y0, out, n, structure, len, lambda, threads, ws,
            );
            return;
        }
        ScanSchedule::Chunked => {}
    }
    let jl = structure.jac_len(n);
    let chunks = threads;
    let chunk_len = len.div_ceil(chunks);
    ws.ensure(chunks * jl, chunks * n, chunks * n);
    let ScanWorkspace { comp_a, comp_b, carry } = ws;
    let comp_a = &mut comp_a[..chunks * jl];
    let comp_b = &mut comp_b[..chunks * n];
    let carry = &mut carry[..chunks * n];

    std::thread::scope(|scope| {
        for (c, (ca, cb)) in comp_a.chunks_mut(jl).zip(comp_b.chunks_mut(n)).enumerate() {
            let lo = (c * chunk_len).min(len);
            let hi = ((c + 1) * chunk_len).min(len);
            scope.spawn(move || {
                compose_range_kalman(a, b, z, lo, hi, lambda, ca, cb, n, structure);
            });
        }
    });

    carry[..n].copy_from_slice(y0);
    for c in 0..chunks - 1 {
        let (done, rest) = carry.split_at_mut((c + 1) * n);
        let prev = &done[c * n..];
        let cur = &mut rest[..n];
        apply_a(structure, &comp_a[c * jl..(c + 1) * jl], prev, cur, n);
        for j in 0..n {
            cur[j] += comp_b[c * n + j];
        }
    }

    let carry = &*carry;
    std::thread::scope(|scope| {
        let mut rest = &mut out[..];
        for c in 0..chunks {
            let lo = (c * chunk_len).min(len);
            let hi = ((c + 1) * chunk_len).min(len);
            if lo >= hi {
                continue;
            }
            let (chunk_out, tail) = rest.split_at_mut((hi - lo) * n);
            rest = tail;
            let entry = &carry[c * n..(c + 1) * n];
            scope.spawn(move || {
                seq_kalman_scan_apply(
                    &a[lo * jl..hi * jl],
                    &b[lo * n..hi * n],
                    &z[lo * n..hi * n],
                    entry,
                    chunk_out,
                    n,
                    structure,
                    hi - lo,
                    lambda,
                );
            });
        }
    });
}

/// One right-to-left composition step of the damped dual map: with
/// `λ_i = cm·exit + cv` as an affine function of the chunk exit, absorb
/// index `i` (`a_next = A_{i+1}`, gradient `g_i`) into `(cm, cv)`.
#[allow(clippy::too_many_arguments)]
fn compose_rev_step_kalman<S: Scalar>(
    structure: JacobianStructure,
    a_next: &[S],
    g_i: &[S],
    s: S,
    cm: &mut [S],
    cv: &mut [S],
    tm: &mut [S],
    tv: &mut [S],
    n: usize,
) {
    match structure {
        JacobianStructure::Dense => {
            for r in 0..n {
                for c in 0..n {
                    let mut acc = S::zero();
                    for kk in 0..n {
                        acc += a_next[kk * n + r] * cm[kk * n + c];
                    }
                    tm[r * n + c] = s * acc;
                }
            }
            cm.copy_from_slice(&tm[..n * n]);
            matvec_t(a_next, cv, tv);
            for j in 0..n {
                cv[j] = s * (tv[j] + g_i[j]);
            }
        }
        JacobianStructure::Diagonal => {
            for j in 0..n {
                cm[j] = s * (a_next[j] * cm[j]);
                cv[j] = s * (a_next[j] * cv[j] + g_i[j]);
            }
        }
        JacobianStructure::Block { k } => {
            for bb in 0..n / k {
                let tile = &a_next[bb * k * k..(bb + 1) * k * k];
                for r in 0..k {
                    for c in 0..k {
                        let mut acc = S::zero();
                        for kk in 0..k {
                            acc += tile[kk * k + r] * cm[bb * k * k + kk * k + c];
                        }
                        tm[bb * k * k + r * k + c] = s * acc;
                    }
                }
            }
            let bl = (n / k) * k * k;
            cm.copy_from_slice(&tm[..bl]);
            block_matvec_t(a_next, cv, tv, n, k);
            for j in 0..n {
                cv[j] = s * (tv[j] + g_i[j]);
            }
        }
    }
}

/// Chunked three-phase damped reverse (dual) scan over one sequence. At
/// `λ = 0` it delegates to the plain reverse kernel family.
#[allow(clippy::too_many_arguments)]
pub fn par_kalman_scan_reverse_ws<S: Scalar>(
    a: &[S],
    g: &[S],
    out: &mut [S],
    n: usize,
    structure: JacobianStructure,
    len: usize,
    lambda: S,
    threads: usize,
    ws: &mut ScanWorkspace<S>,
) {
    if lambda == S::zero() {
        match structure {
            JacobianStructure::Dense => par_scan_reverse_ws(a, g, out, n, len, threads, ws),
            JacobianStructure::Diagonal => {
                par_diag_scan_reverse_ws(a, g, out, n, len, threads, ws)
            }
            JacobianStructure::Block { k } => {
                par_block_scan_reverse_ws(a, g, out, n, k, len, threads, ws)
            }
        }
        return;
    }
    match choose_scan_schedule_observed(len, threads, kalman_combine_flops(structure, n), kalman_apply_flops(structure, n)) {
        ScanSchedule::Sequential => {
            seq_kalman_scan_reverse(a, g, out, n, structure, len, lambda);
            return;
        }
        ScanSchedule::CyclicReduction => {
            super::cr::par_kalman_scan_reverse_cr_ws(
                a, g, out, n, structure, len, lambda, threads, ws,
            );
            return;
        }
        ScanSchedule::Chunked => {}
    }
    let jl = structure.jac_len(n);
    let s = damp_gain(lambda);
    let chunks = threads;
    let chunk_len = len.div_ceil(chunks);
    ws.ensure(chunks * jl, chunks * n, chunks * n);
    let ScanWorkspace { comp_a, comp_b, carry } = ws;
    let comp_a = &mut comp_a[..chunks * jl];
    let comp_b = &mut comp_b[..chunks * n];
    let carry = &mut carry[..chunks * n];

    // Phase 1: per chunk, compose the affine map λ_lo = cm·λ_exit + cv
    // right-to-left (beyond-end A is 0, so the sequence-final element
    // starts the last chunk with cm = 0, cv = s·g).
    std::thread::scope(|scope| {
        for (c, (cm, cv)) in comp_a.chunks_mut(jl).zip(comp_b.chunks_mut(n)).enumerate() {
            let lo = (c * chunk_len).min(len);
            let hi = ((c + 1) * chunk_len).min(len);
            scope.spawn(move || {
                let mut tm = vec![S::zero(); jl];
                let mut tv = vec![S::zero(); n];
                identity_into(structure, cm, n);
                for v in cv.iter_mut() {
                    *v = S::zero();
                }
                for i in (lo..hi).rev() {
                    let g_i = &g[i * n..(i + 1) * n];
                    if i + 1 >= len {
                        for v in cm.iter_mut() {
                            *v = S::zero();
                        }
                        for j in 0..n {
                            cv[j] = s * g_i[j];
                        }
                        continue;
                    }
                    let a_next = &a[(i + 1) * jl..(i + 2) * jl];
                    compose_rev_step_kalman(structure, a_next, g_i, s, cm, cv, &mut tm, &mut tv, n);
                }
            });
        }
    });

    // Phase 2: chunk exits right-to-left (last chunk exit = 0).
    for v in carry[(chunks - 1) * n..].iter_mut() {
        *v = S::zero();
    }
    for c in (0..chunks - 1).rev() {
        let (cur_part, next_part) = carry.split_at_mut((c + 1) * n);
        let next_exit = &next_part[..n];
        let cur = &mut cur_part[c * n..];
        apply_a(structure, &comp_a[(c + 1) * jl..(c + 2) * jl], next_exit, cur, n);
        for j in 0..n {
            cur[j] += comp_b[(c + 1) * n + j];
        }
    }

    // Phase 3: per-chunk damped reverse replay from each exit.
    let carry = &*carry;
    std::thread::scope(|scope| {
        let mut rest = &mut out[..];
        for c in 0..chunks {
            let lo = (c * chunk_len).min(len);
            let hi = ((c + 1) * chunk_len).min(len);
            if lo >= hi {
                continue;
            }
            let (chunk_out, tail) = rest.split_at_mut((hi - lo) * n);
            rest = tail;
            let exit = &carry[c * n..(c + 1) * n];
            scope.spawn(move || {
                seq_kalman_rev_range(a, g, lo, hi, len, exit, chunk_out, n, structure, s);
            });
        }
    });
}

/// Batched damped forward scan over `[B, T, n]` slabs with one λ per
/// sequence. Rows with `λ = 0` run the plain kernels bit-for-bit; damped
/// rows run the fused information-filter kernels against the anchor `z`
/// (the driver's current trajectory guess). Scheduling is keyed on the
/// TOTAL batch size, matching the masking/reproducibility contract of the
/// undamped batched scans.
#[allow(clippy::too_many_arguments)]
pub fn par_kalman_scan_apply_batch_ws<S: Scalar>(
    a: &[S],
    b: &[S],
    z: &[S],
    y0s: &[S],
    out: &mut [S],
    n: usize,
    structure: JacobianStructure,
    t_len: usize,
    batch: usize,
    lambdas: &[S],
    active: Option<&[bool]>,
    threads: usize,
    ws: &mut ScanWorkspace<S>,
) {
    let jl = structure.jac_len(n);
    debug_assert_eq!(a.len(), batch * t_len * jl);
    debug_assert_eq!(b.len(), batch * t_len * n);
    debug_assert_eq!(z.len(), batch * t_len * n);
    debug_assert_eq!(y0s.len(), batch * n);
    debug_assert_eq!(out.len(), batch * t_len * n);
    debug_assert_eq!(lambdas.len(), batch);
    let idx = active_indices(batch, active);
    if idx.is_empty() || t_len == 0 {
        return;
    }
    if batch == 1 {
        par_kalman_scan_apply_ws(a, b, z, y0s, out, n, structure, t_len, lambdas[0], threads, ws);
        return;
    }
    let slab = t_len * n;
    let slab_a = t_len * jl;
    if threads <= 1 {
        for &s in &idx {
            seq_kalman_scan_apply(
                &a[s * slab_a..(s + 1) * slab_a],
                &b[s * slab..(s + 1) * slab],
                &z[s * slab..(s + 1) * slab],
                &y0s[s * n..(s + 1) * n],
                &mut out[s * slab..(s + 1) * slab],
                n,
                structure,
                t_len,
                lambdas[s],
            );
        }
        return;
    }
    let mut slabs: Vec<Option<&mut [S]>> = out.chunks_mut(slab).map(Some).collect();
    if batch >= threads {
        let workers = threads.min(idx.len());
        let mut buckets: Vec<Vec<(usize, &mut [S])>> = (0..workers).map(|_| Vec::new()).collect();
        for (k, &s) in idx.iter().enumerate() {
            buckets[k % workers].push((s, slabs[s].take().unwrap()));
        }
        std::thread::scope(|scope| {
            for bucket in buckets {
                scope.spawn(move || {
                    for (s, out_slab) in bucket {
                        seq_kalman_scan_apply(
                            &a[s * slab_a..(s + 1) * slab_a],
                            &b[s * slab..(s + 1) * slab],
                            &z[s * slab..(s + 1) * slab],
                            &y0s[s * n..(s + 1) * n],
                            out_slab,
                            n,
                            structure,
                            t_len,
                            lambdas[s],
                        );
                    }
                });
            }
        });
        return;
    }
    // Few big sequences: intra-sequence chunking, divisor keyed on the
    // total batch for masking-invariant accumulation order.
    let cps = (threads / batch).max(2);
    std::thread::scope(|scope| {
        for &s in &idx {
            let out_slab = slabs[s].take().unwrap();
            scope.spawn(move || {
                let mut local_ws = ScanWorkspace::new();
                par_kalman_scan_apply_ws(
                    &a[s * slab_a..(s + 1) * slab_a],
                    &b[s * slab..(s + 1) * slab],
                    &z[s * slab..(s + 1) * slab],
                    &y0s[s * n..(s + 1) * n],
                    out_slab,
                    n,
                    structure,
                    t_len,
                    lambdas[s],
                    cps,
                    &mut local_ws,
                );
            });
        }
    });
}

/// Batched damped reverse (dual) scan over `[B, T, n]` slabs with one λ per
/// sequence — the backward-pass counterpart of
/// [`par_kalman_scan_apply_batch_ws`], reusing each row's last accepted
/// forward λ. Rows with `λ = 0` run the plain reverse kernels bit-for-bit.
#[allow(clippy::too_many_arguments)]
pub fn par_kalman_scan_reverse_batch_ws<S: Scalar>(
    a: &[S],
    g: &[S],
    out: &mut [S],
    n: usize,
    structure: JacobianStructure,
    t_len: usize,
    batch: usize,
    lambdas: &[S],
    active: Option<&[bool]>,
    threads: usize,
    ws: &mut ScanWorkspace<S>,
) {
    let jl = structure.jac_len(n);
    debug_assert_eq!(a.len(), batch * t_len * jl);
    debug_assert_eq!(g.len(), batch * t_len * n);
    debug_assert_eq!(out.len(), batch * t_len * n);
    debug_assert_eq!(lambdas.len(), batch);
    let idx = active_indices(batch, active);
    if idx.is_empty() || t_len == 0 {
        return;
    }
    if batch == 1 {
        par_kalman_scan_reverse_ws(a, g, out, n, structure, t_len, lambdas[0], threads, ws);
        return;
    }
    let slab = t_len * n;
    let slab_a = t_len * jl;
    if threads <= 1 {
        for &s in &idx {
            seq_kalman_scan_reverse(
                &a[s * slab_a..(s + 1) * slab_a],
                &g[s * slab..(s + 1) * slab],
                &mut out[s * slab..(s + 1) * slab],
                n,
                structure,
                t_len,
                lambdas[s],
            );
        }
        return;
    }
    let mut slabs: Vec<Option<&mut [S]>> = out.chunks_mut(slab).map(Some).collect();
    if batch >= threads {
        let workers = threads.min(idx.len());
        let mut buckets: Vec<Vec<(usize, &mut [S])>> = (0..workers).map(|_| Vec::new()).collect();
        for (k, &s) in idx.iter().enumerate() {
            buckets[k % workers].push((s, slabs[s].take().unwrap()));
        }
        std::thread::scope(|scope| {
            for bucket in buckets {
                scope.spawn(move || {
                    for (s, out_slab) in bucket {
                        seq_kalman_scan_reverse(
                            &a[s * slab_a..(s + 1) * slab_a],
                            &g[s * slab..(s + 1) * slab],
                            out_slab,
                            n,
                            structure,
                            t_len,
                            lambdas[s],
                        );
                    }
                });
            }
        });
        return;
    }
    let cps = (threads / batch).max(2);
    std::thread::scope(|scope| {
        for &s in &idx {
            let out_slab = slabs[s].take().unwrap();
            scope.spawn(move || {
                let mut local_ws = ScanWorkspace::new();
                par_kalman_scan_reverse_ws(
                    &a[s * slab_a..(s + 1) * slab_a],
                    &g[s * slab..(s + 1) * slab],
                    out_slab,
                    n,
                    structure,
                    t_len,
                    lambdas[s],
                    cps,
                    &mut local_ws,
                );
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scan::par_scan_apply_batch_ws;
    use crate::util::rng::Rng;

    fn random_case(
        n: usize,
        jl: usize,
        len: usize,
        seed: u64,
    ) -> (Vec<f64>, Vec<f64>, Vec<f64>, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let mut a = vec![0.0; len * jl];
        let mut b = vec![0.0; len * n];
        let mut z = vec![0.0; len * n];
        let mut y0 = vec![0.0; n];
        rng.fill_normal(&mut a, 0.5);
        rng.fill_normal(&mut b, 1.0);
        rng.fill_normal(&mut z, 1.0);
        rng.fill_normal(&mut y0, 1.0);
        (a, b, z, y0)
    }

    const STRUCTS: [(JacobianStructure, usize); 3] = [
        (JacobianStructure::Dense, 4),
        (JacobianStructure::Diagonal, 4),
        (JacobianStructure::Block { k: 2 }, 4),
    ];

    /// λ = 0 must route to the plain kernels bit-for-bit (the acceptance
    /// bar: the Kalman INVLIN is tolerance-equal — here bitwise — to the
    /// existing solve at zero damping).
    #[test]
    fn lambda_zero_matches_plain_bitwise() {
        for (st, n) in STRUCTS {
            let jl = st.jac_len(n);
            let len = 64;
            let (a, b, z, y0) = random_case(n, jl, len, 11);
            let mut plain = vec![0.0; len * n];
            match st {
                JacobianStructure::Dense => seq_scan_apply(&a, &b, &y0, &mut plain, n, len),
                JacobianStructure::Diagonal => {
                    seq_diag_scan_apply(&a, &b, &y0, &mut plain, n, len)
                }
                JacobianStructure::Block { k } => {
                    seq_block_scan_apply(&a, &b, &y0, &mut plain, n, k, len)
                }
            }
            let mut damped = vec![0.0; len * n];
            seq_kalman_scan_apply(&a, &b, &z, &y0, &mut damped, n, st, len, 0.0);
            assert_eq!(plain, damped, "{st:?} seq λ=0");

            let mut ws = ScanWorkspace::new();
            let mut par = vec![0.0; len * n];
            par_kalman_scan_apply_ws(&a, &b, &z, &y0, &mut par, n, st, len, 0.0, 4, &mut ws);
            let mut plain_par = vec![0.0; len * n];
            match st {
                JacobianStructure::Dense => {
                    par_scan_apply_ws(&a, &b, &y0, &mut plain_par, n, len, 4, &mut ws)
                }
                JacobianStructure::Diagonal => {
                    par_diag_scan_apply_ws(&a, &b, &y0, &mut plain_par, n, len, 4, &mut ws)
                }
                JacobianStructure::Block { k } => {
                    par_block_scan_apply_ws(&a, &b, &y0, &mut plain_par, n, k, len, 4, &mut ws)
                }
            }
            assert_eq!(plain_par, par, "{st:?} par λ=0");
        }
    }

    /// The damped output must satisfy its defining recurrence
    /// `(1+λ)·ŷ_i = A_i ŷ_{i−1} + b_i + λ z_i`.
    #[test]
    fn damped_seq_satisfies_recurrence() {
        for (st, n) in STRUCTS {
            let jl = st.jac_len(n);
            let len = 40;
            let lambda = 0.7;
            let (a, b, z, y0) = random_case(n, jl, len, 23);
            let mut out = vec![0.0; len * n];
            seq_kalman_scan_apply(&a, &b, &z, &y0, &mut out, n, st, len, lambda);
            let mut ay = vec![0.0; n];
            for i in 0..len {
                let prev = if i == 0 { &y0[..] } else { &out[(i - 1) * n..i * n] };
                apply_a(st, &a[i * jl..(i + 1) * jl], prev, &mut ay, n);
                for j in 0..n {
                    let lhs = (1.0 + lambda) * out[i * n + j];
                    let rhs = ay[j] + b[i * n + j] + lambda * z[i * n + j];
                    assert!((lhs - rhs).abs() < 1e-12, "{st:?} i={i} j={j}");
                }
            }
        }
    }

    /// State form == anchor + delta form: ŷ = z + Δ where Δ solves the
    /// damped delta system via the PLAIN scan on scaled elements.
    #[test]
    fn damped_equals_delta_form() {
        let n = 4;
        let len = 50;
        let lambda = 1.3;
        let s = 1.0 / (1.0 + lambda);
        let (a, b, z, y0) = random_case(n, n * n, len, 37);
        let mut out = vec![0.0; len * n];
        seq_kalman_scan_apply(&a, &b, &z, &y0, &mut out, n, JacobianStructure::Dense, len, lambda);
        // delta system: (1+λ)Δ_i − A_i Δ_{i−1} = A_i z_{i−1} + b_i − z_i
        let mut sa = vec![0.0; len * n * n];
        let mut sb = vec![0.0; len * n];
        let mut az = vec![0.0; n];
        for i in 0..len {
            for q in 0..n * n {
                sa[i * n * n + q] = s * a[i * n * n + q];
            }
            let zp = if i == 0 { &y0[..] } else { &z[(i - 1) * n..i * n] };
            matvec(&a[i * n * n..(i + 1) * n * n], zp, &mut az);
            for j in 0..n {
                sb[i * n + j] = s * (az[j] + b[i * n + j] - z[i * n + j]);
            }
        }
        let zero0 = vec![0.0; n];
        let mut delta = vec![0.0; len * n];
        seq_scan_apply(&sa, &sb, &zero0, &mut delta, n, len);
        for i in 0..len * n {
            assert!((out[i] - (z[i] + delta[i])).abs() < 1e-10, "i={i}");
        }
    }

    /// λ → ∞ pins the solution to the anchor (zero Newton step).
    #[test]
    fn huge_lambda_pins_to_anchor() {
        for (st, n) in STRUCTS {
            let jl = st.jac_len(n);
            let len = 30;
            let (a, b, z, y0) = random_case(n, jl, len, 41);
            let mut out = vec![0.0; len * n];
            seq_kalman_scan_apply(&a, &b, &z, &y0, &mut out, n, st, len, 1e12);
            for i in 0..len * n {
                assert!((out[i] - z[i]).abs() < 1e-9, "{st:?} i={i}");
            }
        }
    }

    /// The chunked three-phase damped scan must agree with the sequential
    /// damped scan across thread counts (forward).
    #[test]
    fn par_apply_matches_seq_damped() {
        for (st, n) in STRUCTS {
            let jl = st.jac_len(n);
            let len = 257;
            let lambda = 0.4;
            let (a, b, z, y0) = random_case(n, jl, len, 53);
            let mut reference = vec![0.0; len * n];
            seq_kalman_scan_apply(&a, &b, &z, &y0, &mut reference, n, st, len, lambda);
            for threads in [2, 3, 8] {
                let mut ws = ScanWorkspace::new();
                let mut out = vec![0.0; len * n];
                par_kalman_scan_apply_ws(
                    &a, &b, &z, &y0, &mut out, n, st, len, lambda, threads, &mut ws,
                );
                for i in 0..len * n {
                    assert!(
                        (out[i] - reference[i]).abs() < 1e-10,
                        "{st:?} threads={threads} i={i}"
                    );
                }
            }
        }
    }

    /// The reverse damped output must satisfy its defining recurrence
    /// `λ_i = s·(g_i + A_{i+1}ᵀ λ_{i+1})` (beyond-end A = 0).
    #[test]
    fn damped_reverse_satisfies_recurrence() {
        for (st, n) in STRUCTS {
            let jl = st.jac_len(n);
            let len = 33;
            let lambda = 0.9;
            let s = 1.0 / (1.0 + lambda);
            let (a, g, _, _) = random_case(n, jl, len, 67);
            let mut out = vec![0.0; len * n];
            seq_kalman_scan_reverse(&a, &g, &mut out, n, st, len, lambda);
            let mut at = vec![0.0; n];
            for i in 0..len {
                for j in 0..n {
                    let expect = if i + 1 < len {
                        apply_a_t(st, &a[(i + 1) * jl..(i + 2) * jl], &out[(i + 1) * n..(i + 2) * n], &mut at, n);
                        s * (g[i * n + j] + at[j])
                    } else {
                        s * g[i * n + j]
                    };
                    assert!((out[i * n + j] - expect).abs() < 1e-12, "{st:?} i={i} j={j}");
                }
            }
        }
    }

    /// Reverse λ = 0 routes to the plain dual kernels bit-for-bit.
    #[test]
    fn reverse_lambda_zero_matches_plain_bitwise() {
        for (st, n) in STRUCTS {
            let jl = st.jac_len(n);
            let len = 48;
            let (a, g, _, _) = random_case(n, jl, len, 71);
            let mut plain = vec![0.0; len * n];
            match st {
                JacobianStructure::Dense => seq_scan_reverse(&a, &g, &mut plain, n, len),
                JacobianStructure::Diagonal => seq_diag_scan_reverse(&a, &g, &mut plain, n, len),
                JacobianStructure::Block { k } => {
                    seq_block_scan_reverse(&a, &g, &mut plain, n, k, len)
                }
            }
            let mut damped = vec![0.0; len * n];
            seq_kalman_scan_reverse(&a, &g, &mut damped, n, st, len, 0.0);
            assert_eq!(plain, damped, "{st:?} reverse λ=0");
        }
    }

    /// The chunked three-phase damped reverse must agree with the
    /// sequential damped reverse across thread counts.
    #[test]
    fn par_reverse_matches_seq_damped() {
        for (st, n) in STRUCTS {
            let jl = st.jac_len(n);
            let len = 203;
            let lambda = 0.6;
            let (a, g, _, _) = random_case(n, jl, len, 83);
            let mut reference = vec![0.0; len * n];
            seq_kalman_scan_reverse(&a, &g, &mut reference, n, st, len, lambda);
            for threads in [2, 3, 8] {
                let mut ws = ScanWorkspace::new();
                let mut out = vec![0.0; len * n];
                par_kalman_scan_reverse_ws(&a, &g, &mut out, n, st, len, lambda, threads, &mut ws);
                for i in 0..len * n {
                    assert!(
                        (out[i] - reference[i]).abs() < 1e-10,
                        "{st:?} threads={threads} i={i}"
                    );
                }
            }
        }
    }

    /// Diagonal / block damped paths embed into the dense damped path.
    #[test]
    fn structured_damped_embeds_into_dense() {
        let n = 4;
        let k = 2;
        let len = 60;
        let lambda = 0.8;
        let mut rng = Rng::new(97);
        let mut blk = vec![0.0; len * n * k];
        let mut b = vec![0.0; len * n];
        let mut z = vec![0.0; len * n];
        let mut y0 = vec![0.0; n];
        rng.fill_normal(&mut blk, 0.5);
        rng.fill_normal(&mut b, 1.0);
        rng.fill_normal(&mut z, 1.0);
        rng.fill_normal(&mut y0, 1.0);
        // embed blocks into dense
        let mut dense = vec![0.0; len * n * n];
        for i in 0..len {
            for bb in 0..n / k {
                for r in 0..k {
                    for c in 0..k {
                        dense[i * n * n + (bb * k + r) * n + bb * k + c] =
                            blk[i * n * k + bb * k * k + r * k + c];
                    }
                }
            }
        }
        let mut out_blk = vec![0.0; len * n];
        let mut out_dense = vec![0.0; len * n];
        seq_kalman_scan_apply(
            &blk, &b, &z, &y0, &mut out_blk, n, JacobianStructure::Block { k }, len, lambda,
        );
        seq_kalman_scan_apply(
            &dense, &b, &z, &y0, &mut out_dense, n, JacobianStructure::Dense, len, lambda,
        );
        for i in 0..len * n {
            assert!((out_blk[i] - out_dense[i]).abs() < 1e-11, "block i={i}");
        }
    }

    /// Batched kernel: per-row λ (mixed zero / non-zero), masked rows
    /// frozen, agreement with per-sequence calls, across thread counts.
    #[test]
    fn batched_matches_per_sequence_and_freezes_masked() {
        let n = 4;
        let st = JacobianStructure::Dense;
        let jl = st.jac_len(n);
        let t_len = 97;
        let batch = 5;
        let mut rng = Rng::new(131);
        let mut a = vec![0.0; batch * t_len * jl];
        let mut b = vec![0.0; batch * t_len * n];
        let mut z = vec![0.0; batch * t_len * n];
        let mut y0s = vec![0.0; batch * n];
        rng.fill_normal(&mut a, 0.4);
        rng.fill_normal(&mut b, 1.0);
        rng.fill_normal(&mut z, 1.0);
        rng.fill_normal(&mut y0s, 1.0);
        let lambdas = [0.0, 0.5, 2.0, 0.0, 10.0];
        let active = [true, true, false, true, true];
        for threads in [1, 2, 4, 8] {
            let mut ws = ScanWorkspace::new();
            let mut out = vec![-888.0; batch * t_len * n];
            par_kalman_scan_apply_batch_ws(
                &a,
                &b,
                &z,
                &y0s,
                &mut out,
                n,
                st,
                t_len,
                batch,
                &lambdas,
                Some(&active),
                threads,
                &mut ws,
            );
            for s in 0..batch {
                let slab = t_len * n;
                if !active[s] {
                    assert!(
                        out[s * slab..(s + 1) * slab].iter().all(|&v| v == -888.0),
                        "masked row {s} touched (threads={threads})"
                    );
                    continue;
                }
                let mut want = vec![0.0; slab];
                seq_kalman_scan_apply(
                    &a[s * t_len * jl..(s + 1) * t_len * jl],
                    &b[s * slab..(s + 1) * slab],
                    &z[s * slab..(s + 1) * slab],
                    &y0s[s * n..(s + 1) * n],
                    &mut want,
                    n,
                    st,
                    t_len,
                    lambdas[s],
                );
                for i in 0..slab {
                    assert!(
                        (out[s * slab + i] - want[i]).abs() < 1e-10,
                        "row {s} threads={threads} i={i}"
                    );
                }
            }
        }
    }

    /// An all-zero λ batch must be bitwise equal to the plain batched scan
    /// (same scheduling contract, same kernels).
    #[test]
    fn batched_all_zero_lambda_matches_plain_batched() {
        let n = 3;
        let jl = n * n;
        let t_len = 64;
        let batch = 4;
        let mut rng = Rng::new(139);
        let mut a = vec![0.0; batch * t_len * jl];
        let mut b = vec![0.0; batch * t_len * n];
        let mut z = vec![0.0; batch * t_len * n];
        let mut y0s = vec![0.0; batch * n];
        rng.fill_normal(&mut a, 0.4);
        rng.fill_normal(&mut b, 1.0);
        rng.fill_normal(&mut z, 1.0);
        rng.fill_normal(&mut y0s, 1.0);
        let lambdas = vec![0.0; batch];
        for threads in [1, 2, 8] {
            let mut ws = ScanWorkspace::new();
            let mut kalman = vec![0.0; batch * t_len * n];
            par_kalman_scan_apply_batch_ws(
                &a,
                &b,
                &z,
                &y0s,
                &mut kalman,
                n,
                JacobianStructure::Dense,
                t_len,
                batch,
                &lambdas,
                None,
                threads,
                &mut ws,
            );
            let mut plain = vec![0.0; batch * t_len * n];
            par_scan_apply_batch_ws(
                &a, &b, &y0s, &mut plain, n, t_len, batch, None, threads, &mut ws,
            );
            assert_eq!(plain, kalman, "threads={threads}");
        }
    }
}
