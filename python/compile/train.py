"""Layer-2 training steps: Adam on a flat parameter vector.

The Rust coordinator drives training by executing the AOT-compiled
``*_train_step`` artifacts: state is ``(flat_params, adam_m, adam_v, step)``
— plain f32 vectors, so the artifact boundary stays trivial. Each train step
is a single fused HLO module (forward + backward + Adam), the L2 §Perf
requirement (no per-step re-lowering, everything fuses under one jit).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

from . import models

# ---------------------------------------------------------------------------
# Adam (Kingma & Ba 2014) on flat vectors
# ---------------------------------------------------------------------------


def adam_update(params, grads, m, v, step, *, lr=1e-3, b1=0.9, b2=0.999, eps=1e-8):
    """One Adam step; ``step`` is the 1-based update index (i32 scalar)."""
    m = b1 * m + (1.0 - b1) * grads
    v = b2 * v + (1.0 - b2) * grads * grads
    t = step.astype(params.dtype)
    mhat = m / (1.0 - b1**t)
    vhat = v / (1.0 - b2**t)
    params = params - lr * mhat / (jnp.sqrt(vhat) + eps)
    return params, m, v


def clip_by_global_norm(grads, max_norm):
    norm = jnp.sqrt(jnp.sum(grads * grads))
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-12))
    return grads * scale


# ---------------------------------------------------------------------------
# EigenWorms classifier (App. B.3: Adam 3e-4, global-norm clip 1.0)
# ---------------------------------------------------------------------------


def make_worms_fns(key, *, in_dim=6, hidden=24, layers=5, classes=5, use_deer=True, max_iter=100, lr=3e-4):
    """Build (init_flat, unravel, train_step, eval_fn) for the classifier."""
    params0 = models.worms_init(key, in_dim=in_dim, hidden=hidden, layers=layers, classes=classes)
    flat0, unravel = ravel_pytree(params0)

    def loss_fn(flat, xs, labels):
        ce, acc = models.worms_loss_acc(
            unravel(flat), xs, labels, hidden=hidden, use_deer=use_deer, max_iter=max_iter
        )
        return ce, acc

    def train_step(flat, m, v, step, xs, labels):
        (ce, acc), grads = jax.value_and_grad(loss_fn, has_aux=True)(flat, xs, labels)
        grads = clip_by_global_norm(grads, 1.0)
        step = step + 1
        flat, m, v = adam_update(flat, grads, m, v, step, lr=lr)
        return flat, m, v, step, ce, acc

    def eval_fn(flat, xs, labels):
        return loss_fn(flat, xs, labels)

    return flat0, unravel, train_step, eval_fn


# ---------------------------------------------------------------------------
# HNN / NeuralODE (App. B.2: Adam 1e-3, MSE)
# ---------------------------------------------------------------------------


def make_hnn_fns(key, *, hidden=64, depth=6, solver="deer", max_iter=30, lr=1e-3):
    params0 = models.hnn_init(key, hidden=hidden, depth=depth)
    flat0, unravel = ravel_pytree(params0)

    def loss_fn(flat, ts, trajs):
        return models.hnn_loss(unravel(flat), ts, trajs, solver=solver)

    def train_step(flat, m, v, step, ts, trajs):
        loss, grads = jax.value_and_grad(loss_fn)(flat, ts, trajs)
        step = step + 1
        flat, m, v = adam_update(flat, grads, m, v, step, lr=lr)
        return flat, m, v, step, loss

    def eval_fn(flat, ts, trajs):
        return loss_fn(flat, ts, trajs)

    return flat0, unravel, train_step, eval_fn


# ---------------------------------------------------------------------------
# Multi-head GRU / sequential CIFAR (App. B.4: AdamW-ish, clip 1.0)
# ---------------------------------------------------------------------------


def make_mhgru_fns(key, *, in_dim=3, channels=64, heads=8, blocks=2, classes=10, use_deer=True, max_iter=100, lr=2e-3, weight_decay=0.01):
    params0 = models.mhgru_init(key, in_dim=in_dim, channels=channels, heads=heads, blocks=blocks, classes=classes)
    flat0, unravel = ravel_pytree(params0)

    def loss_fn(flat, xs, labels):
        ce, acc = models.mhgru_loss_acc(unravel(flat), xs, labels, use_deer=use_deer, max_iter=max_iter)
        return ce, acc

    def train_step(flat, m, v, step, xs, labels):
        (ce, acc), grads = jax.value_and_grad(loss_fn, has_aux=True)(flat, xs, labels)
        grads = clip_by_global_norm(grads, 1.0)
        step = step + 1
        flat, m, v = adam_update(flat, grads, m, v, step, lr=lr)
        flat = flat * (1.0 - lr * weight_decay)  # decoupled weight decay
        return flat, m, v, step, ce, acc

    def eval_fn(flat, xs, labels):
        return loss_fn(flat, xs, labels)

    return flat0, unravel, train_step, eval_fn
