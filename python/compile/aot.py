"""AOT lowering: JAX (L2, calling the L1 Pallas kernels) → HLO text artifacts.

HLO **text** is the interchange format (NOT ``.serialize()``): jax ≥ 0.5
emits HloModuleProto with 64-bit instruction ids which the runtime's
xla_extension 0.5.1 rejects; the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Outputs into ``artifacts/``:
  * ``<name>.hlo.txt``   — one per entry point,
  * ``<name>_params.bin``— raw little-endian f32 initial parameter vectors,
  * ``manifest.json``    — shapes/dtypes of every artifact's inputs/outputs,
    consumed by ``rust/src/runtime/artifact.rs``.

Python runs ONCE here (``make artifacts``); the Rust binary is self-contained
afterwards.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import deer as deer_mod
from . import train
from .kernels import ref

# ---------------------------------------------------------------------------


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (the aot recipe)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype="f32"):
    return {"shape": list(shape), "dtype": dtype}


def sds(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


# ---------------------------------------------------------------------------
# Artifact registry
# ---------------------------------------------------------------------------

# Default artifact shapes. Kept modest: the runtime targets a 1-core CPU PJRT
# client; EXPERIMENTS.md documents the scaling to paper-size runs.
QS_N, QS_M, QS_T = 16, 16, 512
WORMS = dict(in_dim=6, hidden=16, layers=2, classes=5, batch=4, t=256, lr=3e-4)
HNN = dict(hidden=48, depth=6, batch=2, grid=128, lr=1e-3)
MHGRU = dict(in_dim=3, channels=32, heads=4, blocks=1, classes=10, batch=2, t=128, lr=2e-3)


def build_quickstart(key):
    """DEER GRU forward through the full L1 path (Pallas cell kernel +
    Pallas scan) and the sequential baseline, same params/shapes."""
    n, m, t = QS_N, QS_M, QS_T
    params = ref.gru_init(key, n, m)

    def deer_fwd(params, h0, xs):
        return (deer_mod.deer_gru_fused(params, h0, xs, n=n, m=m, block=256),)

    def seq_fwd(params, h0, xs):
        return (ref.gru_seq(params, h0, xs, n=n, m=m),)

    args = (sds(params.shape), sds((n,)), sds((t, m)))
    io = {
        "inputs": [
            {"name": "params", **spec(params.shape)},
            {"name": "h0", **spec((n,))},
            {"name": "xs", **spec((t, m))},
        ],
        "outputs": [{"name": "ys", **spec((t, n))}],
        "meta": {"n": n, "m": m, "t": t, "param_len": int(params.shape[0])},
    }
    return [
        ("deer_gru_fwd", deer_fwd, args, io, params),
        ("gru_seq_fwd", seq_fwd, args, io, None),
    ]


def build_worms(key):
    cfg = WORMS
    flat0, _, train_step, eval_fn = train.make_worms_fns(
        key,
        in_dim=cfg["in_dim"],
        hidden=cfg["hidden"],
        layers=cfg["layers"],
        classes=cfg["classes"],
        use_deer=True,
        lr=cfg["lr"],
    )
    p = int(flat0.shape[0])
    b, t = cfg["batch"], cfg["t"]
    ts_args = (
        sds((p,)),
        sds((p,)),
        sds((p,)),
        sds((), jnp.int32),
        sds((b, t, cfg["in_dim"])),
        sds((b,), jnp.int32),
    )
    ts_io = {
        "inputs": [
            {"name": "params", **spec((p,))},
            {"name": "adam_m", **spec((p,))},
            {"name": "adam_v", **spec((p,))},
            {"name": "step", **spec((), "i32")},
            {"name": "xs", **spec((b, t, cfg["in_dim"]))},
            {"name": "labels", **spec((b,), "i32")},
        ],
        "outputs": [
            {"name": "params", **spec((p,))},
            {"name": "adam_m", **spec((p,))},
            {"name": "adam_v", **spec((p,))},
            {"name": "step", **spec((), "i32")},
            {"name": "loss", **spec(())},
            {"name": "acc", **spec(())},
        ],
        "meta": {**cfg, "param_len": p},
    }
    ev_args = (sds((p,)), sds((b, t, cfg["in_dim"])), sds((b,), jnp.int32))
    ev_io = {
        "inputs": [
            {"name": "params", **spec((p,))},
            {"name": "xs", **spec((b, t, cfg["in_dim"]))},
            {"name": "labels", **spec((b,), "i32")},
        ],
        "outputs": [{"name": "loss", **spec(())}, {"name": "acc", **spec(())}],
        "meta": {**cfg, "param_len": p},
    }
    return [
        ("worms_train_step", lambda *a: tuple(train_step(*a)), ts_args, ts_io, flat0),
        ("worms_eval", lambda *a: tuple(eval_fn(*a)), ev_args, ev_io, None),
    ]


def build_hnn(key):
    cfg = HNN
    flat0, _, train_step, eval_fn = train.make_hnn_fns(
        key, hidden=cfg["hidden"], depth=cfg["depth"], solver="deer", lr=cfg["lr"]
    )
    flat0_rk4, _, train_step_rk4, _ = train.make_hnn_fns(
        key, hidden=cfg["hidden"], depth=cfg["depth"], solver="rk4", lr=cfg["lr"]
    )
    del flat0_rk4  # identical init (same key)
    p = int(flat0.shape[0])
    b, l = cfg["batch"], cfg["grid"]
    args = (sds((p,)), sds((p,)), sds((p,)), sds((), jnp.int32), sds((l,)), sds((b, l, 8)))
    io = {
        "inputs": [
            {"name": "params", **spec((p,))},
            {"name": "adam_m", **spec((p,))},
            {"name": "adam_v", **spec((p,))},
            {"name": "step", **spec((), "i32")},
            {"name": "ts", **spec((l,))},
            {"name": "trajs", **spec((b, l, 8))},
        ],
        "outputs": [
            {"name": "params", **spec((p,))},
            {"name": "adam_m", **spec((p,))},
            {"name": "adam_v", **spec((p,))},
            {"name": "step", **spec((), "i32")},
            {"name": "loss", **spec(())},
        ],
        "meta": {**cfg, "param_len": p},
    }
    ev_args = (sds((p,)), sds((l,)), sds((b, l, 8)))
    ev_io = {
        "inputs": [
            {"name": "params", **spec((p,))},
            {"name": "ts", **spec((l,))},
            {"name": "trajs", **spec((b, l, 8))},
        ],
        "outputs": [{"name": "loss", **spec(())}],
        "meta": {**cfg, "param_len": p},
    }
    return [
        ("hnn_train_step_deer", lambda *a: tuple(train_step(*a)), args, io, flat0),
        ("hnn_train_step_rk4", lambda *a: tuple(train_step_rk4(*a)), args, io, None),
        ("hnn_eval", lambda *a: (eval_fn(*a),), ev_args, ev_io, None),
    ]


def build_mhgru(key):
    cfg = MHGRU
    flat0, _, train_step, eval_fn = train.make_mhgru_fns(
        key,
        in_dim=cfg["in_dim"],
        channels=cfg["channels"],
        heads=cfg["heads"],
        blocks=cfg["blocks"],
        classes=cfg["classes"],
        use_deer=True,
        lr=cfg["lr"],
    )
    p = int(flat0.shape[0])
    b, t = cfg["batch"], cfg["t"]
    args = (
        sds((p,)),
        sds((p,)),
        sds((p,)),
        sds((), jnp.int32),
        sds((b, t, cfg["in_dim"])),
        sds((b,), jnp.int32),
    )
    io = {
        "inputs": [
            {"name": "params", **spec((p,))},
            {"name": "adam_m", **spec((p,))},
            {"name": "adam_v", **spec((p,))},
            {"name": "step", **spec((), "i32")},
            {"name": "xs", **spec((b, t, cfg["in_dim"]))},
            {"name": "labels", **spec((b,), "i32")},
        ],
        "outputs": [
            {"name": "params", **spec((p,))},
            {"name": "adam_m", **spec((p,))},
            {"name": "adam_v", **spec((p,))},
            {"name": "step", **spec((), "i32")},
            {"name": "loss", **spec(())},
            {"name": "acc", **spec(())},
        ],
        "meta": {**cfg, "param_len": p},
    }
    ev_args = (sds((p,)), sds((b, t, cfg["in_dim"])), sds((b,), jnp.int32))
    ev_io = {
        "inputs": [
            {"name": "params", **spec((p,))},
            {"name": "xs", **spec((b, t, cfg["in_dim"]))},
            {"name": "labels", **spec((b,), "i32")},
        ],
        "outputs": [{"name": "loss", **spec(())}, {"name": "acc", **spec(())}],
        "meta": {**cfg, "param_len": p},
    }
    return [
        ("mhgru_train_step", lambda *a: tuple(train_step(*a)), args, io, flat0),
        ("mhgru_eval", lambda *a: tuple(eval_fn(*a)), ev_args, ev_io, None),
    ]


BUILDERS = {
    "quickstart": build_quickstart,
    "worms": build_worms,
    "hnn": build_hnn,
    "mhgru": build_mhgru,
}


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    ap.add_argument("--only", default=None, help="comma-separated builder subset")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    names = list(BUILDERS) if args.only is None else args.only.split(",")
    key = jax.random.PRNGKey(args.seed)

    manifest = {"artifacts": []}
    manifest_path = os.path.join(args.out, "manifest.json")
    if os.path.exists(manifest_path):
        with open(manifest_path) as f:
            manifest = json.load(f)

    for group in names:
        gkey = jax.random.fold_in(key, hash(group) % (2**31))
        for name, fn, arg_specs, io, init_params in BUILDERS[group](gkey):
            print(f"[aot] lowering {name} ...", flush=True)
            lowered = jax.jit(fn).lower(*arg_specs)
            text = to_hlo_text(lowered)
            path = os.path.join(args.out, f"{name}.hlo.txt")
            with open(path, "w") as f:
                f.write(text)
            entry = {"name": name, "file": f"{name}.hlo.txt", **io}
            if init_params is not None:
                import numpy as np

                pbin = f"{name}_params.bin"
                np.asarray(init_params, dtype="<f4").tofile(os.path.join(args.out, pbin))
                entry["params_file"] = pbin
            # replace any stale entry
            manifest["artifacts"] = [a for a in manifest["artifacts"] if a["name"] != name]
            manifest["artifacts"].append(entry)
            print(f"[aot]   wrote {path} ({len(text)} chars)")

    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] manifest: {manifest_path} ({len(manifest['artifacts'])} artifacts)")


if __name__ == "__main__":
    main()
