"""Layer-1 Pallas kernel: blocked associative affine scan (paper eq. 10/11).

This is the `L_G⁻¹` hot-spot of DEER expressed as a Pallas kernel with the
same three-phase schedule as the Rust `scan::par` implementation and the one
a TPU would run:

1. ``_aggregate_kernel`` — grid over sequence blocks; each block reduces its
   elements to a single affine pair ``(A_blk, b_blk)``.
2. A tiny host-side carry scan over the ``T/blk`` block aggregates.
3. ``_apply_kernel`` — grid over blocks; each block replays the O(n²)
   recurrence from its entry state.

TPU mapping (DESIGN.md §Hardware-Adaptation): each block's working set in
VMEM is ``blk·(n² + 2n)·4 B`` (A-tile + b-tile + running pair) — e.g.
``blk=256, n=16`` → ~0.3 MiB, far under the ~16 MiB VMEM budget; block-level
composition is an (n×n)·(n×n) matmul chain that maps onto the MXU for
n ≥ 8 (padded to 8×128 tiles below that). The kernels MUST run with
``interpret=True`` here: real-TPU lowering emits Mosaic custom-calls the CPU
PJRT plugin cannot execute.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 256


def _aggregate_kernel(a_ref, b_ref, agg_a_ref, agg_b_ref):
    """Compose all elements of one block into a single (A, b) pair."""
    a = a_ref[...]  # (blk, n, n)
    b = b_ref[...]  # (blk, n)

    def step(carry, ab):
        acc_a, acc_b = carry
        ai, bi = ab
        return (ai @ acc_a, ai @ acc_b + bi), 0

    n = a.shape[-1]
    init = (jnp.eye(n, dtype=a.dtype), jnp.zeros((n,), a.dtype))
    (agg_a, agg_b), _ = jax.lax.scan(step, init, (a, b))
    agg_a_ref[...] = agg_a[None]
    agg_b_ref[...] = agg_b[None]


def _apply_kernel(a_ref, b_ref, entry_ref, out_ref):
    """Replay the recurrence within one block from its entry state."""
    a = a_ref[...]
    b = b_ref[...]
    y0 = entry_ref[0]

    def step(h, ab):
        ai, bi = ab
        y = ai @ h + bi
        return y, y

    _, ys = jax.lax.scan(step, y0, (a, b))
    out_ref[...] = ys


@functools.partial(jax.jit, static_argnames=("block",))
def pallas_affine_scan(a, b, y0, *, block: int = DEFAULT_BLOCK):
    """``y_i = A_i y_{i-1} + b_i`` with ``y_0 = y0`` via the blocked Pallas
    schedule. a: (T, n, n), b: (T, n), y0: (n,) → (T, n).

    T must be a multiple of ``block`` (callers pad; DEER's benchmark lengths
    are powers of two). Falls back to a single block when T < block.
    """
    t, n, _ = a.shape
    blk = min(block, t)
    assert t % blk == 0, f"sequence length {t} not a multiple of block {blk}"
    nblocks = t // blk

    # Phase 1: per-block aggregates.
    agg_a, agg_b = pl.pallas_call(
        _aggregate_kernel,
        grid=(nblocks,),
        in_specs=[
            pl.BlockSpec((blk, n, n), lambda c: (c, 0, 0)),
            pl.BlockSpec((blk, n), lambda c: (c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, n, n), lambda c: (c, 0, 0)),
            pl.BlockSpec((1, n), lambda c: (c, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nblocks, n, n), a.dtype),
            jax.ShapeDtypeStruct((nblocks, n), a.dtype),
        ],
        interpret=True,
    )(a, b)

    # Phase 2: carry across blocks (length T/blk — negligible).
    def carry_step(y, ab):
        ai, bi = ab
        y2 = ai @ y + bi
        return y2, y

    _, entries = jax.lax.scan(carry_step, y0, (agg_a, agg_b))

    # Phase 3: per-block apply.
    out = pl.pallas_call(
        _apply_kernel,
        grid=(nblocks,),
        in_specs=[
            pl.BlockSpec((blk, n, n), lambda c: (c, 0, 0)),
            pl.BlockSpec((blk, n), lambda c: (c, 0)),
            pl.BlockSpec((1, n), lambda c: (c, 0)),
        ],
        out_specs=pl.BlockSpec((blk, n), lambda c: (c, 0)),
        out_shape=jax.ShapeDtypeStruct((t, n), a.dtype),
        interpret=True,
    )(a, b, entries)
    return out


def vmem_bytes(block: int, n: int, elem: int = 4) -> int:
    """Estimated per-block VMEM working set (documented in DESIGN.md §Perf)."""
    return block * (n * n + 2 * n) * elem + 2 * (n * n + n) * elem


def mxu_utilization_estimate(n: int) -> float:
    """Fraction of the 128×128 MXU systolic array a block-compose matmul can
    fill: DEER's per-element (n×n)·(n×n) products tile the MXU only for
    n ≥ 128; below that utilization ≈ (n/128)² per issue, partially recovered
    by batching 8 elements per pass."""
    frac = min(1.0, (n / 128.0) ** 2 * 8.0)
    return max(frac, 1.0 / (128.0 * 16.0))
