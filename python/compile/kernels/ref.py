"""Pure-jnp correctness oracles for the Layer-1 Pallas kernels.

Everything here is the *reference semantics*: the sequential affine
recurrence (paper eq. 11), the associative combine (eq. 10), and the GRU cell
with its analytic state Jacobian. Kernels in this package and the Rust engine
are both validated against these functions.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def combine(later, earlier):
    """Associative operator of eq. (10): ``(A_l, b_l) • (A_e, b_e)``.

    Elements are pairs ``(A, b)`` representing ``y ↦ A y + b``; ``later``
    composes *after* ``earlier``. Shapes broadcast over leading axes, so this
    works both element-wise and inside ``jax.lax.associative_scan``.
    """
    a_l, b_l = later
    a_e, b_e = earlier
    a = jnp.einsum("...ij,...jk->...ik", a_l, a_e)
    b = jnp.einsum("...ij,...j->...i", a_l, b_e) + b_l
    return a, b


def seq_affine_scan(a, b, y0):
    """Sequential ``y_i = A_i y_{i-1} + b_i`` via lax.scan.

    a: (T, n, n), b: (T, n), y0: (n,). Returns (T, n).
    """

    def step(carry, ab):
        ai, bi = ab
        y = ai @ carry + bi
        return y, y

    _, ys = jax.lax.scan(step, y0, (a, b))
    return ys


def _swapped_combine(earlier, later):
    """``associative_scan`` folds (accumulated-prefix, new-element) — the
    accumulated prefix is the *earlier* transform, so adapt argument order."""
    return combine(later, earlier)


def assoc_affine_scan(a, b, y0):
    """Parallel evaluation of the same recurrence with
    ``jax.lax.associative_scan`` (the paper's §3.5 implementation note)."""
    # Fold y0 into the first element: b_1' = A_1 y0 + b_1.
    b = b.at[0].add(a[0] @ y0)
    _, b_cum = jax.lax.associative_scan(_swapped_combine, (a, b))
    return b_cum


def seq_reverse_scan(a, g):
    """Dual recurrence of eq. (7): ``λ_i = g_i + A_{i+1}ᵀ λ_{i+1}``.

    a: (T, n, n) (a[i] propagates step i-1 → i), g: (T, n). Returns λ: (T, n).
    """
    t = a.shape[0]
    # Shift: position i pairs with A_{i+1}; the last position has no successor.
    a_shift = jnp.concatenate([a[1:], jnp.zeros_like(a[:1])], axis=0)

    def step(carry, ag):
        ai, gi = ag
        lam = gi + ai.T @ carry
        return lam, lam

    _, lams = jax.lax.scan(step, jnp.zeros_like(g[0]), (a_shift[::-1], g[::-1]))
    return lams[::-1]


def assoc_reverse_scan(a, g):
    """Parallel dual scan: same recurrence evaluated with associative_scan
    over the reversed sequence of transposed propagators."""
    a_shift = jnp.concatenate([a[1:], jnp.zeros_like(a[:1])], axis=0)
    a_rev = jnp.swapaxes(a_shift[::-1], -1, -2)
    _, lam_rev = jax.lax.associative_scan(_swapped_combine, (a_rev, g[::-1]))
    return lam_rev[::-1]


# ---------------------------------------------------------------------------
# GRU reference (layout-compatible with rust/src/cells/gru.rs)
# ---------------------------------------------------------------------------


def gru_num_params(n, m):
    return 3 * n * m + 3 * n * n + 6 * n


def gru_init(key, n, m, dtype=jnp.float32):
    """Flat GRU parameter vector, uniform(-1/√n, 1/√n) — identical layout to
    the Rust ``Gru``: ``[W_ir,W_iz,W_in | W_hr,W_hz,W_hn | b_ir,b_iz,b_in,
    b_hr,b_hz,b_hn]``.
    """
    bound = 1.0 / float(n) ** 0.5
    return jax.random.uniform(key, (gru_num_params(n, m),), dtype, -bound, bound)


def gru_unpack(params, n, m):
    """Split the flat vector into weight views."""
    o = 0
    wi = []
    for _ in range(3):
        wi.append(params[o : o + n * m].reshape(n, m))
        o += n * m
    wh = []
    for _ in range(3):
        wh.append(params[o : o + n * n].reshape(n, n))
        o += n * n
    bs = []
    for _ in range(6):
        bs.append(params[o : o + n])
        o += n
    return wi, wh, bs


def gru_step(params, h, x, *, n, m):
    """One GRU step ``h' = f(h, x)`` (PyTorch convention; matches Rust)."""
    (w_ir, w_iz, w_in), (w_hr, w_hz, w_hn), (b_ir, b_iz, b_in, b_hr, b_hz, b_hn) = gru_unpack(
        params, n, m
    )
    r = jax.nn.sigmoid(w_ir @ x + b_ir + w_hr @ h + b_hr)
    z = jax.nn.sigmoid(w_iz @ x + b_iz + w_hz @ h + b_hz)
    mg = w_hn @ h + b_hn
    nh = jnp.tanh(w_in @ x + b_in + r * mg)
    return (1.0 - z) * nh + z * h


def gru_seq(params, h0, xs, *, n, m):
    """Sequential GRU evaluation: xs (T, m) → ys (T, n)."""

    def step(h, x):
        h2 = gru_step(params, h, x, n=n, m=m)
        return h2, h2

    _, ys = jax.lax.scan(step, h0, xs)
    return ys


def gru_f_and_jac(params, h, x, *, n, m):
    """Fused f + analytic ∂f/∂h — the reference for the Pallas GRU kernel."""
    (w_ir, w_iz, w_in), (w_hr, w_hz, w_hn), (b_ir, b_iz, b_in, b_hr, b_hz, b_hn) = gru_unpack(
        params, n, m
    )
    r = jax.nn.sigmoid(w_ir @ x + b_ir + w_hr @ h + b_hr)
    z = jax.nn.sigmoid(w_iz @ x + b_iz + w_hz @ h + b_hz)
    mg = w_hn @ h + b_hn
    nh = jnp.tanh(w_in @ x + b_in + r * mg)
    f = (1.0 - z) * nh + z * h

    dn = 1.0 - nh * nh
    dr = r * (1.0 - r)
    dz = z * (1.0 - z)
    c1 = ((1.0 - z) * dn * r)[:, None]  # W_hn coefficient
    c2 = ((1.0 - z) * dn * mg * dr)[:, None]  # W_hr coefficient
    c3 = ((h - nh) * dz)[:, None]  # W_hz coefficient
    jac = c1 * w_hn + c2 * w_hr + c3 * w_hz + jnp.diag(z)
    return f, jac
