"""Layer-1 Pallas kernel: fused GRU cell evaluation + analytic Jacobian.

Table 5 of the paper profiles DEER's iteration and shows FUNCEVAL (the f and
``jacfwd`` evaluation) is a major cost next to INVLIN. This kernel fuses the
two: gate activations are computed once and reused for both the new state and
the analytic ∂f/∂h rows — the optimization the Rust engine mirrors in
``cells::Gru::jacobian`` (see EXPERIMENTS.md §Perf).

Grid: sequence blocks of ``blk`` steps; each invocation computes
``f(h_{i-1}, x_i)`` and the n×n Jacobian for its block, fully vectorized
(no per-step loop — all ops are (blk, ·) tensor ops that map onto VPU/MXU
lanes). VMEM per block ≈ ``blk·(n² + 2n + m)·4`` bytes.

interpret=True as required for CPU PJRT execution.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 256


def _gru_kernel(h_ref, x_ref, wi_ref, wh_ref, b_ref, f_ref, jac_ref):
    h = h_ref[...]  # (blk, n) — previous states (shifted trajectory guess)
    x = x_ref[...]  # (blk, m)
    wi = wi_ref[...]  # (3, n, m): W_ir, W_iz, W_in
    wh = wh_ref[...]  # (3, n, n): W_hr, W_hz, W_hn
    b = b_ref[...]  # (6, n): b_ir, b_iz, b_in, b_hr, b_hz, b_hn

    a_r = x @ wi[0].T + h @ wh[0].T + b[0] + b[3]
    a_z = x @ wi[1].T + h @ wh[1].T + b[1] + b[4]
    mg = h @ wh[2].T + b[5]
    r = jax.nn.sigmoid(a_r)
    z = jax.nn.sigmoid(a_z)
    nh = jnp.tanh(x @ wi[2].T + b[2] + r * mg)
    f = (1.0 - z) * nh + z * h
    f_ref[...] = f

    dn = 1.0 - nh * nh
    dr = r * (1.0 - r)
    dz = z * (1.0 - z)
    c1 = (1.0 - z) * dn * r  # → W_hn
    c2 = (1.0 - z) * dn * mg * dr  # → W_hr
    c3 = (h - nh) * dz  # → W_hz
    n = h.shape[-1]
    jac = (
        c1[:, :, None] * wh[2][None]
        + c2[:, :, None] * wh[0][None]
        + c3[:, :, None] * wh[1][None]
        + z[:, :, None] * jnp.eye(n, dtype=h.dtype)[None]
    )
    jac_ref[...] = jac


@functools.partial(jax.jit, static_argnames=("n", "m", "block"))
def pallas_gru_f_jac(params, h_prev, xs, *, n, m, block: int = DEFAULT_BLOCK):
    """Fused (f, ∂f/∂h) along a trajectory.

    params: flat GRU vector (Rust-compatible layout, see ``ref.gru_init``);
    h_prev: (T, n) shifted states; xs: (T, m). Returns f (T, n), jac (T, n, n).
    """
    t = h_prev.shape[0]
    blk = min(block, t)
    assert t % blk == 0, f"T={t} not a multiple of block {blk}"
    nblocks = t // blk

    wi = params[: 3 * n * m].reshape(3, n, m)
    wh = params[3 * n * m : 3 * n * m + 3 * n * n].reshape(3, n, n)
    bs = params[3 * n * m + 3 * n * n :].reshape(6, n)

    f, jac = pl.pallas_call(
        _gru_kernel,
        grid=(nblocks,),
        in_specs=[
            pl.BlockSpec((blk, n), lambda c: (c, 0)),
            pl.BlockSpec((blk, m), lambda c: (c, 0)),
            pl.BlockSpec((3, n, m), lambda c: (0, 0, 0)),
            pl.BlockSpec((3, n, n), lambda c: (0, 0, 0)),
            pl.BlockSpec((6, n), lambda c: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((blk, n), lambda c: (c, 0)),
            pl.BlockSpec((blk, n, n), lambda c: (c, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((t, n), h_prev.dtype),
            jax.ShapeDtypeStruct((t, n, n), h_prev.dtype),
        ],
        interpret=True,
    )(h_prev, xs, wi, wh, bs)
    return f, jac


def vmem_bytes(block: int, n: int, m: int, elem: int = 4) -> int:
    """Per-block VMEM estimate for the fused kernel."""
    io = block * (n * n + 2 * n + m)
    weights = 3 * n * m + 3 * n * n + 6 * n
    return (io + weights) * elem
