"""Layer-2: the DEER fixed-point iteration in JAX (paper §3, App. B.1).

``deer_iteration`` mirrors the paper's reference code (App. B.1) with the
same structure: shifter → FUNCEVAL (f + Jacobians) → GTMULT (rhs assembly) →
INVLIN (associative scan) inside a ``lax.while_loop`` with the dtype-derived
tolerance of §3.5.

``deer_rnn`` specialises it to the single-shift RNN case (eq. 11) and wires a
``jax.custom_vjp`` implementing the paper's eq. (7) backward pass: **one**
dual scan + a parallel parameter VJP — this is what makes training-time
speedups exceed forward speedups (Fig. 2 bottom).
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp

from .kernels import ref
from .kernels.assoc_scan import pallas_affine_scan
from .kernels.gru_cell import pallas_gru_f_jac


def dtype_tol(dtype) -> float:
    """§3.5: 1e-4 for single precision, 1e-7 for double."""
    return 1e-7 if jnp.dtype(dtype) == jnp.float64 else 1e-4


# ---------------------------------------------------------------------------
# Generic DEER iteration (App. B.1)
# ---------------------------------------------------------------------------


def deer_iteration(
    invlin: Callable,
    func: Callable,
    shifter_func: Callable,
    p_num: int,
    params,
    xinput,
    invlin_params,
    shifter_func_params,
    yinit_guess,
    max_iter: int = 100,
):
    """Generic DEER solver, a line-for-line functional port of App. B.1.

    * ``invlin(gts, rhs, invlin_params)`` — applies ``L_G⁻¹``.
    * ``func(ytparams, x, params)`` — the non-linear f at one sample.
    * ``shifter_func(yt, shifter_params)`` — list of P shifted trajectories.
    """
    jacfunc = jax.vmap(jax.jacfwd(func, argnums=0), in_axes=(0, 0, None))
    func2 = jax.vmap(func, in_axes=(0, 0, None))
    dtype = yinit_guess.dtype
    tol = dtype_tol(dtype)

    def iter_func(iter_inp):
        err, yt, iiter = iter_inp
        ytparams = shifter_func(yt, shifter_func_params)
        gts = [-gt for gt in jacfunc(ytparams, xinput, params)]  # FUNCEVAL
        rhs = func2(ytparams, xinput, params)  # FUNCEVAL
        rhs += sum(
            jnp.einsum("...ij,...j->...i", gt, ytp) for gt, ytp in zip(gts, ytparams)
        )  # GTMULT
        yt_next = invlin(gts, rhs, invlin_params)  # INVLIN
        err = jnp.max(jnp.abs(yt_next - yt))
        return err, yt_next, iiter + 1

    def cond_func(iter_inp):
        err, _, iiter = iter_inp
        return jnp.logical_and(err > tol, iiter < max_iter)

    err = jnp.array(1e10, dtype=dtype)
    iiter = jnp.array(0, dtype=jnp.int32)
    _, yt, _ = jax.lax.while_loop(cond_func, iter_func, (err, yinit_guess, iiter))
    return yt


# ---------------------------------------------------------------------------
# RNN materialisation (eq. 11) with the eq. (7) backward pass
# ---------------------------------------------------------------------------


def _rnn_fixed_point(step_fn, params, h0, xs, guess, max_iter, scan_impl):
    """Run the DEER Newton iteration for ``y_i = f(params, y_{i-1}, x_i)``."""
    jac_fn = jax.vmap(jax.jacfwd(step_fn, argnums=1), in_axes=(None, 0, 0))
    f_fn = jax.vmap(step_fn, in_axes=(None, 0, 0))
    tol = dtype_tol(guess.dtype)

    def one_iter(yt):
        h_prev = jnp.concatenate([h0[None], yt[:-1]], axis=0)
        jac = jac_fn(params, h_prev, xs)  # (T, n, n) — FUNCEVAL
        f = f_fn(params, h_prev, xs)  # (T, n)
        rhs = f - jnp.einsum("tij,tj->ti", jac, h_prev)  # GTMULT
        return scan_impl(jac, rhs, h0)  # INVLIN

    def body(state):
        err, yt, it = state
        yt_next = one_iter(yt)
        err = jnp.max(jnp.abs(yt_next - yt))
        return err, yt_next, it + 1

    def cond(state):
        err, _, it = state
        return jnp.logical_and(err > tol, it < max_iter)

    err0 = jnp.array(jnp.inf, dtype=guess.dtype)
    _, ys, iters = jax.lax.while_loop(cond, body, (err0, guess, jnp.array(0, jnp.int32)))
    return ys, iters


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 5, 6))
def deer_rnn(step_fn, params, h0, xs, guess, max_iter=100, use_pallas_scan=False):
    """DEER evaluation of an RNN; differentiable via the paper's eq. (7).

    ``step_fn(params, h, x) -> h'`` defines the recurrence. Returns ys (T, n).
    ``guess`` is the initial trajectory (zeros, or the previous training
    step's solution — App. B.2 warm start).
    """
    scan_impl = pallas_affine_scan if use_pallas_scan else ref.assoc_affine_scan
    ys, _ = _rnn_fixed_point(step_fn, params, h0, xs, guess, max_iter, scan_impl)
    return ys


def _deer_rnn_fwd(step_fn, params, h0, xs, guess, max_iter, use_pallas_scan):
    scan_impl = pallas_affine_scan if use_pallas_scan else ref.assoc_affine_scan
    ys, _ = _rnn_fixed_point(step_fn, params, h0, xs, guess, max_iter, scan_impl)
    return ys, (params, h0, xs, ys)


def _deer_rnn_bwd(step_fn, max_iter, use_pallas_scan, res, g):
    params, h0, xs, ys = res
    h_prev = jnp.concatenate([h0[None], ys[:-1]], axis=0)

    # Jacobians along the converged trajectory.
    jac = jax.vmap(jax.jacfwd(step_fn, argnums=1), in_axes=(None, 0, 0))(params, h_prev, xs)

    # ONE dual scan: λ_i = g_i + J_{i+1}ᵀ λ_{i+1}  (eq. 7's L_G⁻¹ dual).
    lam = ref.assoc_reverse_scan(jac, g)

    # Parallel per-step VJPs, summed for parameters.
    def step_vjp(h, x, lam_i):
        _, vjp = jax.vjp(lambda p, hh, xx: step_fn(p, hh, xx), params, h, x)
        return vjp(lam_i)

    dparams_steps, _, dxs = jax.vmap(step_vjp)(h_prev, xs, lam)
    dparams = jax.tree_util.tree_map(lambda a: jnp.sum(a, axis=0), dparams_steps)

    # dL/dh0 flows through step 1 only (later steps' h-cotangents are already
    # folded into λ by the dual scan).
    _, vjp0 = jax.vjp(lambda hh: step_fn(params, hh, xs[0]), h0)
    (dh0,) = vjp0(lam[0])

    dguess = jnp.zeros_like(ys)  # the fixed point is guess-independent
    return dparams, dh0, dxs, dguess


deer_rnn.defvjp(_deer_rnn_fwd, _deer_rnn_bwd)


# ---------------------------------------------------------------------------
# GRU front-ends (the paper's benchmark subject)
# ---------------------------------------------------------------------------


def gru_step_fn(n, m):
    """step_fn closure for :func:`deer_rnn` using the reference GRU."""

    def step(params, h, x):
        return ref.gru_step(params, h, x, n=n, m=m)

    return step


def deer_gru(params, h0, xs, guess=None, *, n, m, max_iter=100, use_pallas_scan=False):
    """DEER evaluation of a GRU (flat Rust-compatible params)."""
    if guess is None:
        guess = jnp.zeros((xs.shape[0], n), xs.dtype)
    return deer_rnn(gru_step_fn(n, m), params, h0, xs, guess, max_iter, use_pallas_scan)


def deer_gru_fused(params, h0, xs, guess=None, *, n, m, max_iter=100, block=256):
    """DEER GRU forward using the fused Pallas cell kernel for FUNCEVAL and
    the Pallas scan for INVLIN — the all-L1 hot path that gets AOT-compiled
    into the quickstart artifact. Forward-only (wrap in
    ``jax.lax.stop_gradient`` land; training uses :func:`deer_gru`)."""
    t = xs.shape[0]
    if guess is None:
        guess = jnp.zeros((t, n), xs.dtype)
    tol = dtype_tol(xs.dtype)

    def body(state):
        err, yt, it = state
        h_prev = jnp.concatenate([h0[None], yt[:-1]], axis=0)
        f, jac = pallas_gru_f_jac(params, h_prev, xs, n=n, m=m, block=min(block, t))
        rhs = f - jnp.einsum("tij,tj->ti", jac, h_prev)
        yt_next = pallas_affine_scan(jac, rhs, h0, block=min(block, t))
        err = jnp.max(jnp.abs(yt_next - yt))
        return err, yt_next, it + 1

    def cond(state):
        err, _, it = state
        return jnp.logical_and(err > tol, it < max_iter)

    err0 = jnp.array(jnp.inf, dtype=xs.dtype)
    _, ys, _ = jax.lax.while_loop(cond, body, (err0, guess, jnp.array(0, jnp.int32)))
    return ys
