"""Layer-2 models for the paper's experiments.

* :func:`hnn_*` — Hamiltonian Neural Network (§4.2): an MLP Hamiltonian whose
  symplectic gradient defines the NeuralODE dynamics, trained on two-body
  trajectories by rolling the ODE out with DEER (or RK4 baseline).
* :func:`worms_*` — the EigenWorms classifier (§4.3, App. B.3): encoder →
  L × [GRU → MLP] with residual+LayerNorm → decoder → mean pool.
* :func:`mhgru_*` — the multi-head strided GRU block (§4.4, App. B.4) for
  sequential-CIFAR-style inputs.

All parameters live in pytrees of plain arrays; ``jax.flatten_util`` gives
the flat vector the Rust coordinator exchanges with the AOT artifacts.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import deer as deer_mod
from .kernels import ref

# ---------------------------------------------------------------------------
# Small building blocks
# ---------------------------------------------------------------------------


def dense_init(key, n_in, n_out, dtype=jnp.float32):
    kw, _ = jax.random.split(key)
    bound = 1.0 / jnp.sqrt(jnp.asarray(n_in, jnp.float32))
    w = jax.random.uniform(kw, (n_out, n_in), dtype, -bound, bound)
    b = jnp.zeros((n_out,), dtype)
    return {"w": w, "b": b}


def dense(p, x):
    return x @ p["w"].T + p["b"]


def layer_norm(x, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps)


def mlp_init(key, sizes, dtype=jnp.float32):
    keys = jax.random.split(key, len(sizes) - 1)
    return [dense_init(k, a, b, dtype) for k, a, b in zip(keys, sizes[:-1], sizes[1:])]


def mlp_apply(layers, x, act=jax.nn.relu, final_act=False):
    for i, p in enumerate(layers):
        x = dense(p, x)
        if i + 1 < len(layers) or final_act:
            x = act(x)
    return x


# ---------------------------------------------------------------------------
# Hamiltonian Neural Network (§4.2 / App. B.2)
# ---------------------------------------------------------------------------

HNN_STATE = 8  # two-body: (x1, y1, vx1, vy1, x2, y2, vx2, vy2)


def hnn_init(key, hidden=64, depth=6, state=HNN_STATE):
    """App. B.2: 6 linear layers, softplus activations, scalar output."""
    sizes = [state] + [hidden] * (depth - 1) + [1]
    return mlp_init(key, sizes)


def hnn_hamiltonian(params, s):
    return mlp_apply(params, s, act=jax.nn.softplus)[0]


def hnn_dynamics(params, t, s):
    """ds/dt = J_sym ∇H with the canonical symplectic structure on
    (q1, q2 | p1, p2) ordering (positions first, velocities last per pair are
    re-indexed internally)."""
    del t
    grad_h = jax.grad(lambda ss: hnn_hamiltonian(params, ss))(s)
    # state layout: [x1, y1, vx1, vy1, x2, y2, vx2, vy2]
    # dq/dt = ∂H/∂p ; dp/dt = −∂H/∂q, pairing (x1,vx1), (y1,vy1), ...
    q_idx = jnp.array([0, 1, 4, 5])
    p_idx = jnp.array([2, 3, 6, 7])
    ds = jnp.zeros_like(s)
    ds = ds.at[q_idx].set(grad_h[p_idx])
    ds = ds.at[p_idx].set(-grad_h[q_idx])
    return ds


def hnn_rollout_deer(params, ts, y0, max_iter=30):
    from .ode import deer_ode_solve

    return deer_ode_solve(hnn_dynamics, params, ts, y0, max_iter)


def hnn_rollout_rk4(params, ts, y0):
    from .ode import rk4_solve

    return rk4_solve(hnn_dynamics, params, ts, y0)


def hnn_loss(params, ts, trajs, solver="deer"):
    """MSE between rolled-out and reference trajectories. trajs: (B, L, 8)."""
    roll = hnn_rollout_deer if solver == "deer" else hnn_rollout_rk4
    pred = jax.vmap(lambda y0: roll(params, ts, y0))(trajs[:, 0])
    return jnp.mean((pred - trajs) ** 2)


# ---------------------------------------------------------------------------
# EigenWorms classifier (§4.3 / App. B.3)
# ---------------------------------------------------------------------------


def worms_init(key, *, in_dim=6, hidden=24, layers=5, classes=5):
    keys = jax.random.split(key, 2 + 2 * layers)
    p = {
        "encoder": dense_init(keys[0], in_dim, hidden),
        "decoder": dense_init(keys[1], hidden, classes),
        "grus": [],
        "mlps": [],
    }
    for i in range(layers):
        p["grus"].append(ref.gru_init(keys[2 + 2 * i], hidden, hidden))
        p["mlps"].append(mlp_init(keys[3 + 2 * i], [hidden, hidden, hidden]))
    return p


def worms_forward(params, xs, *, hidden, use_deer=True, max_iter=100):
    """xs: (T, in_dim) → logits (classes,). App. B.3 architecture: encoder,
    then per layer GRU + MLP each with residual + LayerNorm, decoder, mean
    over the sequence."""
    h = dense(params["encoder"], xs)  # (T, d)
    n = hidden
    for gru_p, mlp_p in zip(params["grus"], params["mlps"]):
        if use_deer:
            ys = deer_mod.deer_rnn(
                deer_mod.gru_step_fn(n, n),
                gru_p,
                jnp.zeros((n,), h.dtype),
                h,
                jnp.zeros_like(h),
                max_iter,
                False,
            )
        else:
            ys = ref.gru_seq(gru_p, jnp.zeros((n,), h.dtype), h, n=n, m=n)
        h = layer_norm(h + ys)
        h = layer_norm(h + mlp_apply(mlp_p, h))
    logits = dense(params["decoder"], h)  # (T, classes)
    return jnp.mean(logits, axis=0)


def worms_loss_acc(params, xs, labels, *, hidden, use_deer=True, max_iter=100):
    """Batched cross-entropy + accuracy. xs: (B, T, in), labels: (B,)."""
    logits = jax.vmap(lambda x: worms_forward(params, x, hidden=hidden, use_deer=use_deer, max_iter=max_iter))(xs)
    logp = jax.nn.log_softmax(logits)
    ce = -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))
    acc = jnp.mean((jnp.argmax(logits, axis=1) == labels).astype(jnp.float32))
    return ce, acc


# ---------------------------------------------------------------------------
# Multi-head strided GRU (§4.4 / App. B.4)
# ---------------------------------------------------------------------------


def mhgru_block_init(key, *, channels, heads):
    assert channels % heads == 0
    c = channels // heads
    keys = jax.random.split(key, heads + 2)
    return {
        "heads": [ref.gru_init(keys[i], c, c) for i in range(heads)],
        "up": dense_init(keys[-2], channels, 2 * channels),  # pre-GLU
    }


def _strided_gru(gru_p, xs, stride, *, n, use_deer, max_iter):
    """GRU with recurrence stride 2^k: the sequence splits into `stride`
    independent interleaved subsequences (the DEER shift s=stride), each
    evaluated in parallel."""
    t, _ = xs.shape
    pad = (-t) % stride
    xs_p = jnp.pad(xs, ((0, pad), (0, 0)))
    tt = xs_p.shape[0]
    lanes = xs_p.reshape(tt // stride, stride, n).transpose(1, 0, 2)  # (stride, T/stride, c)

    def run(lane):
        if use_deer:
            return deer_mod.deer_rnn(
                deer_mod.gru_step_fn(n, n),
                gru_p,
                jnp.zeros((n,), xs.dtype),
                lane,
                jnp.zeros_like(lane),
                max_iter,
                False,
            )
        return ref.gru_seq(gru_p, jnp.zeros((n,), xs.dtype), lane, n=n, m=n)

    ys = jax.vmap(run)(lanes)  # (stride, T/stride, c)
    ys = ys.transpose(1, 0, 2).reshape(tt, n)
    return ys[:t]


def mhgru_block_apply(p, xs, *, use_deer=True, max_iter=100):
    """One composite layer (App. B.4): multi-head strided GRU → linear 2×
    up-projection → GLU → residual → LayerNorm. xs: (T, channels)."""
    # dims are static (weight shapes): up-projection is (2C, C).
    channels = p["up"]["w"].shape[1]
    heads = len(p["heads"])
    c = channels // heads
    outs = []
    for k, gru_p in enumerate(p["heads"]):
        stride = 2 ** (k % 8)
        outs.append(_strided_gru(gru_p, xs[:, k * c : (k + 1) * c], stride, n=c, use_deer=use_deer, max_iter=max_iter))
    y = jnp.concatenate(outs, axis=-1)
    y = dense(p["up"], y)
    y = y[:, :channels] * jax.nn.sigmoid(y[:, channels:])  # GLU
    return layer_norm(xs + y)


def mhgru_init(key, *, in_dim=3, channels=64, heads=8, blocks=2, classes=10):
    keys = jax.random.split(key, blocks + 2)
    return {
        "encoder": dense_init(keys[0], in_dim, channels),
        "blocks": [mhgru_block_init(keys[1 + i], channels=channels, heads=heads) for i in range(blocks)],
        "decoder": dense_init(keys[-1], channels, classes),
    }


def mhgru_forward(params, xs, *, use_deer=True, max_iter=100):
    """xs: (T, in_dim) → logits (classes,)."""
    h = dense(params["encoder"], xs)
    for blk in params["blocks"]:
        h = mhgru_block_apply(blk, h, use_deer=use_deer, max_iter=max_iter)
    logits = dense(params["decoder"], h)
    return jnp.mean(logits, axis=0)


def mhgru_loss_acc(params, xs, labels, *, use_deer=True, max_iter=100):
    logits = jax.vmap(lambda x: mhgru_forward(params, x, use_deer=use_deer, max_iter=max_iter))(xs)
    logp = jax.nn.log_softmax(logits)
    ce = -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))
    acc = jnp.mean((jnp.argmax(logits, axis=1) == labels).astype(jnp.float32))
    return ce, acc
