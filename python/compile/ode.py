"""Layer-2: DEER-ODE in JAX (paper §3.3) and the RK4 baseline.

The forward solve is the eq. (9) recurrence evaluated with an associative
scan and iterated to convergence inside ``lax.while_loop``. The backward pass
exploits the Newton property: at the converged trajectory ``y*`` the
iteration map Φ has ``∂Φ/∂y = 0`` (quadratic convergence), so
``dy*/dθ = ∂Φ/∂θ`` and the VJP of a *single* iteration (with the trajectory
input stopped) is the exact gradient — the practical realisation of eqs.
(6)/(7) for the ODE case.

``expm_pade`` / ``phi1_pade`` are differentiable matrix exponentials
(Padé-6 + fixed scaling-squaring) — ``jax.scipy.linalg.expm`` is avoided to
keep the lowered HLO free of data-dependent control flow.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernels import ref


def expm_pade(a, squarings: int = 8, order: int = 12):
    """Differentiable matrix exponential: Taylor(order) + 2^squarings scaling.

    Valid for ||a||₁ ≲ 2^squarings / 2 — ample for DEER-ODE's ``−G·Δt``
    blocks on the workloads in this repo. Taylor (not Padé) on purpose: a
    Padé denominator needs ``jnp.linalg.solve``, which lowers to a typed-FFI
    LAPACK custom-call that the runtime's xla_extension 0.5.1 cannot load;
    the Taylor form is pure matmuls and keeps the artifact loadable. At
    ||a_s|| ≤ 0.5 the order-12 truncation error is ~1e-13, below f32 noise.
    """
    n = a.shape[-1]
    a_s = a / (2.0**squarings)
    eye = jnp.eye(n, dtype=a.dtype)
    e = eye
    term = eye
    for k in range(1, order + 1):
        term = term @ a_s / k
        e = e + term
    for _ in range(squarings):
        e = e @ e
    return e


def phi1_pade(a, squarings: int = 8):
    """φ₁(A) = (e^A − I)A⁻¹ via the augmented-matrix trick (singular-safe)."""
    n = a.shape[-1]
    zeros = jnp.zeros((n, n), a.dtype)
    eye = jnp.eye(n, dtype=a.dtype)
    aug = jnp.block([[a, eye], [zeros, zeros]])
    e = expm_pade(aug, squarings)
    return e[:n, n:]


def _deer_ode_one_iter(f, params, ts, y0, yt):
    """One DEER-ODE Newton step: linearise on ``yt``, solve eq. (9) exactly.

    ``f(params, t, y) -> dy/dt``; ``yt`` is the full (L, n) trajectory guess
    (with ``yt[0] == y0``). Returns the updated (L, n) trajectory.
    """
    jac_f = jax.vmap(jax.jacfwd(f, argnums=2), in_axes=(None, 0, 0))
    f_v = jax.vmap(f, in_axes=(None, 0, 0))
    jacs = jac_f(params, ts, yt)  # (L, n, n)
    fv = f_v(params, ts, yt)  # (L, n)
    g_node = -jacs
    z_node = fv - jnp.einsum("tij,tj->ti", jacs, yt)

    dts = (ts[1:] - ts[:-1])[:, None, None]
    g_c = 0.5 * (g_node[:-1] + g_node[1:])  # midpoint interpolation (App. A.5)
    z_c = 0.5 * (z_node[:-1] + z_node[1:])
    m = -g_c * dts
    abar = jax.vmap(expm_pade)(m)
    phi = jax.vmap(phi1_pade)(m)
    bbar = dts[:, :, 0] * jnp.einsum("tij,tj->ti", phi, z_c)

    ys = ref.assoc_affine_scan(abar, bbar, y0)  # (L-1, n)
    return jnp.concatenate([y0[None], ys], axis=0)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 4))
def deer_ode_solve(f, params, ts, y0, max_iter=50, guess=None):
    """Solve ``dy/dt = f(params, t, y)`` on the grid ``ts`` with DEER.

    Returns the (L, n) trajectory. Differentiable w.r.t. ``params`` and
    ``y0`` via the fixed-point implicit VJP described in the module docs.
    """
    ys, _ = _fixed_point(f, params, ts, y0, max_iter, guess)
    return ys


def _fixed_point(f, params, ts, y0, max_iter, guess):
    l = ts.shape[0]
    n = y0.shape[0]
    tol = 1e-7 if jnp.dtype(y0.dtype) == jnp.float64 else 1e-4
    if guess is None:
        guess = jnp.tile(y0[None], (l, 1))
    else:
        guess = guess.at[0].set(y0)

    def body(state):
        err, yt, it = state
        yt_next = _deer_ode_one_iter(f, params, ts, y0, yt)
        err = jnp.max(jnp.abs(yt_next - yt))
        return err, yt_next, it + 1

    def cond(state):
        err, _, it = state
        return jnp.logical_and(err > tol, it < max_iter)

    err0 = jnp.array(jnp.inf, dtype=y0.dtype)
    _, ys, iters = jax.lax.while_loop(cond, body, (err0, guess, jnp.array(0, jnp.int32)))
    return ys, iters


def _deer_ode_fwd(f, params, ts, y0, max_iter, guess):
    ys, _ = _fixed_point(f, params, ts, y0, max_iter, guess)
    return ys, (params, ts, y0, ys)


def _deer_ode_bwd(f, max_iter, res, g):
    params, ts, y0, ys = res
    # One-iteration VJP at the fixed point (∂Φ/∂y = 0 there).
    ystar = jax.lax.stop_gradient(ys)

    def phi(p, y0_):
        return _deer_ode_one_iter(f, p, ts, y0_, ystar)

    _, vjp = jax.vjp(phi, params, y0)
    dparams, dy0 = vjp(g)
    dts = jnp.zeros_like(ts)
    dguess = None
    return dparams, dts, dy0, dguess


deer_ode_solve.defvjp(_deer_ode_fwd, _deer_ode_bwd)


def rk4_solve(f, params, ts, y0):
    """Classic fixed-grid RK4 over ``ts`` — the differentiable sequential
    baseline (stand-in for the paper's adaptive RK45; fixed-grid keeps the
    lowered HLO static, and on a uniform fine grid the two coincide to well
    below the training-noise floor)."""

    def step(y, tt):
        t0, t1 = tt
        h = t1 - t0
        k1 = f(params, t0, y)
        k2 = f(params, t0 + h / 2, y + h / 2 * k1)
        k3 = f(params, t0 + h / 2, y + h / 2 * k2)
        k4 = f(params, t1, y + h * k3)
        y2 = y + h / 6 * (k1 + 2 * k2 + 2 * k3 + k4)
        return y2, y2

    _, ys = jax.lax.scan(step, y0, (ts[:-1], ts[1:]))
    return jnp.concatenate([y0[None], ys], axis=0)
