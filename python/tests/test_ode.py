"""DEER-ODE (L2) correctness: closed forms, RK4 agreement, gradient checks,
differentiable expm/φ₁."""

import jax
import jax.numpy as jnp
import numpy as np

from compile.ode import deer_ode_solve, expm_pade, phi1_pade, rk4_solve


def test_expm_rotation():
    t = 0.9
    a = jnp.array([[0.0, -t], [t, 0.0]])
    want = jnp.array([[jnp.cos(t), -jnp.sin(t)], [jnp.sin(t), jnp.cos(t)]])
    np.testing.assert_allclose(expm_pade(a), want, rtol=1e-5, atol=1e-5)


def test_expm_differentiable():
    def f(s):
        return jnp.sum(expm_pade(jnp.array([[0.0, -s], [s, 0.0]])))

    g = jax.grad(f)(0.7)
    # d/ds [2cos s] = −2 sin s (off-diagonals cancel: −cos' terms)
    want = jax.grad(lambda s: 2 * jnp.cos(s) + 0.0 * s)(0.7)
    np.testing.assert_allclose(g, want, rtol=1e-4, atol=1e-4)


def test_phi1_scalar():
    for x in [0.5, -1.0, 1e-7]:
        a = jnp.array([[x]])
        got = phi1_pade(a)[0, 0]
        want = (np.exp(x) - 1.0) / x if abs(x) > 1e-6 else 1.0 + x / 2
        np.testing.assert_allclose(got, want, rtol=1e-4)


def _decay(params, t, y):
    del t
    return -params * y


def test_linear_ode_closed_form():
    ts = jnp.linspace(0.0, 2.0, 65)
    ys = deer_ode_solve(_decay, jnp.asarray(1.0), ts, jnp.array([1.0]), 30)
    np.testing.assert_allclose(ys[:, 0], jnp.exp(-ts), rtol=1e-3, atol=1e-4)


def test_deer_matches_rk4_nonlinear():
    def vdp(params, t, y):
        del t
        mu = params
        return jnp.array([y[1], mu * (1 - y[0] ** 2) * y[1] - y[0]])

    ts = jnp.linspace(0.0, 4.0, 513)
    y0 = jnp.array([1.0, 0.0])
    y_deer = deer_ode_solve(vdp, jnp.asarray(0.6), ts, y0, 50)
    y_rk4 = rk4_solve(vdp, jnp.asarray(0.6), ts, y0)
    np.testing.assert_allclose(y_deer, y_rk4, rtol=5e-2, atol=5e-3)


def test_implicit_gradient_close_to_rk4_gradient():
    ts = jnp.linspace(0.0, 1.0, 65)
    y0 = jnp.array([1.0])
    target = jnp.exp(-1.3 * ts)[:, None]

    def loss_deer(k):
        return jnp.mean((deer_ode_solve(_decay, k, ts, y0, 30) - target) ** 2)

    def loss_rk4(k):
        return jnp.mean((rk4_solve(_decay, k, ts, y0) - target) ** 2)

    g_d = jax.grad(loss_deer)(1.0)
    g_r = jax.grad(loss_rk4)(1.0)
    np.testing.assert_allclose(g_d, g_r, rtol=2e-2)


def test_y0_gradient():
    ts = jnp.linspace(0.0, 1.0, 33)

    def loss(y0s):
        return jnp.sum(deer_ode_solve(_decay, jnp.asarray(1.0), ts, jnp.array([y0s]), 30))

    g = jax.grad(loss)(1.0)
    # d/dy0 Σ e^{-t} y0 = Σ e^{-t}
    want = float(jnp.sum(jnp.exp(-ts)))
    np.testing.assert_allclose(g, want, rtol=1e-3)


def test_ic_pinned():
    ts = jnp.linspace(0.0, 1.0, 17)
    ys = deer_ode_solve(_decay, jnp.asarray(0.5), ts, jnp.array([2.0]), 20)
    assert float(ys[0, 0]) == 2.0
