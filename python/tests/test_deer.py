"""L2 DEER correctness: fixed point equals sequential evaluation (Fig. 3),
gradients equal BPTT (eq. 7), warm starts, App. B.1 generic form."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import deer as deer_mod
from compile.kernels import ref


@settings(max_examples=10, deadline=None)
@given(
    n=st.integers(min_value=1, max_value=6),
    m=st.integers(min_value=1, max_value=4),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_deer_matches_sequential(n, m, seed):
    t = 256
    key = jax.random.PRNGKey(seed)
    params = ref.gru_init(key, n, m)
    xs = jax.random.normal(jax.random.fold_in(key, 1), (t, m))
    h0 = jnp.zeros((n,))
    want = ref.gru_seq(params, h0, xs, n=n, m=m)
    got = deer_mod.deer_gru(params, h0, xs, n=n, m=m)
    # Fig. 3: agreement at single-precision tolerance.
    np.testing.assert_allclose(got, want, rtol=5e-3, atol=5e-4)


def test_deer_gradient_matches_bptt():
    key = jax.random.PRNGKey(5)
    n, m, t = 4, 3, 128
    params = ref.gru_init(key, n, m)
    xs = jax.random.normal(jax.random.fold_in(key, 1), (t, m))
    h0 = jnp.zeros((n,))
    w = jax.random.normal(jax.random.fold_in(key, 2), (t, n))

    def loss_seq(p):
        return jnp.sum(w * ref.gru_seq(p, h0, xs, n=n, m=m))

    def loss_deer(p):
        return jnp.sum(w * deer_mod.deer_gru(p, h0, xs, n=n, m=m))

    g_seq = jax.grad(loss_seq)(params)
    g_deer = jax.grad(loss_deer)(params)
    scale = jnp.max(jnp.abs(g_seq))
    np.testing.assert_allclose(g_deer / scale, g_seq / scale, rtol=2e-3, atol=2e-4)


def test_deer_input_and_h0_gradients():
    key = jax.random.PRNGKey(6)
    n, m, t = 3, 2, 64
    params = ref.gru_init(key, n, m)
    xs = jax.random.normal(jax.random.fold_in(key, 1), (t, m))
    h0 = jax.random.normal(jax.random.fold_in(key, 2), (n,)) * 0.2

    def loss_seq(h0_, xs_):
        return jnp.sum(ref.gru_seq(params, h0_, xs_, n=n, m=m) ** 2)

    def loss_deer(h0_, xs_):
        ys = deer_mod.deer_rnn(
            deer_mod.gru_step_fn(n, m), params, h0_, xs_, jnp.zeros((t, n)), 100, False
        )
        return jnp.sum(ys**2)

    gh_s, gx_s = jax.grad(loss_seq, argnums=(0, 1))(h0, xs)
    gh_d, gx_d = jax.grad(loss_deer, argnums=(0, 1))(h0, xs)
    np.testing.assert_allclose(gh_d, gh_s, rtol=1e-2, atol=1e-4)
    np.testing.assert_allclose(gx_d, gx_s, rtol=1e-2, atol=1e-4)


def test_warm_start_is_fixed_point():
    key = jax.random.PRNGKey(7)
    n, m, t = 3, 2, 128
    params = ref.gru_init(key, n, m)
    xs = jax.random.normal(jax.random.fold_in(key, 1), (t, m))
    h0 = jnp.zeros((n,))
    ys = deer_mod.deer_gru(params, h0, xs, n=n, m=m)
    ys2 = deer_mod.deer_gru(params, h0, xs, guess=ys, n=n, m=m)
    np.testing.assert_allclose(ys, ys2, rtol=1e-5, atol=1e-5)


def test_generic_deer_iteration_appendix_b1():
    """The App. B.1 generic form reproduces the GRU fixed point."""
    key = jax.random.PRNGKey(8)
    n, m, t = 3, 2, 64
    params = ref.gru_init(key, n, m)
    xs = jax.random.normal(jax.random.fold_in(key, 1), (t, m))
    h0 = jnp.zeros((n,))

    def func(ytparams, x, p):
        (h_prev,) = ytparams
        return ref.gru_step(p, h_prev, x, n=n, m=m)

    def shifter(yt, h0_):
        return [jnp.concatenate([h0_[None], yt[:-1]], axis=0)]

    def invlin(gts, rhs, h0_):
        (g,) = gts
        return ref.assoc_affine_scan(-g, rhs, h0_)

    ys = deer_mod.deer_iteration(
        invlin, func, shifter, 1, params, xs, h0, h0, jnp.zeros((t, n))
    )
    want = ref.gru_seq(params, h0, xs, n=n, m=m)
    np.testing.assert_allclose(ys, want, rtol=5e-3, atol=5e-4)


def test_deer_fused_matches_plain():
    key = jax.random.PRNGKey(9)
    n, m, t = 4, 4, 256
    params = ref.gru_init(key, n, m)
    xs = jax.random.normal(jax.random.fold_in(key, 1), (t, m))
    h0 = jnp.zeros((n,))
    a = deer_mod.deer_gru(params, h0, xs, n=n, m=m)
    b = deer_mod.deer_gru_fused(params, h0, xs, n=n, m=m, block=64)
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)
