"""AOT path: HLO-text lowering, manifest schema, param binary format."""

import json
import os
import struct

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot


def test_to_hlo_text_roundtrip_tiny_fn():
    def fn(x, y):
        return (jnp.matmul(x, y) + 2.0,)

    spec = jax.ShapeDtypeStruct((2, 2), jnp.float32)
    lowered = jax.jit(fn).lower(spec, spec)
    text = aot.to_hlo_text(lowered)
    # HLO text essentials the Rust loader depends on:
    assert "HloModule" in text
    assert "f32[2,2]" in text
    # return_tuple=True → tuple-shaped root
    assert "(f32[2,2]{1,0}) tuple" in text


def test_to_hlo_text_pallas_lowers_to_plain_hlo():
    """interpret=True Pallas must lower to plain HLO ops (no custom-call that
    the CPU PJRT plugin can't run, no Mosaic)."""
    from compile.kernels.assoc_scan import pallas_affine_scan

    t, n = 64, 3
    lowered = jax.jit(
        lambda a, b, y0: (pallas_affine_scan(a, b, y0, block=32),)
    ).lower(
        jax.ShapeDtypeStruct((t, n, n), jnp.float32),
        jax.ShapeDtypeStruct((t, n), jnp.float32),
        jax.ShapeDtypeStruct((n,), jnp.float32),
    )
    text = aot.to_hlo_text(lowered)
    assert "mosaic" not in text.lower()
    assert "API_VERSION_TYPED_FFI" not in text


def test_spec_helper():
    s = aot.spec((4, 8))
    assert s == {"shape": [4, 8], "dtype": "f32"}
    s = aot.spec((), "i32")
    assert s == {"shape": [], "dtype": "i32"}


def test_manifest_written_by_main(tmp_path):
    """End-to-end aot.py main on the smallest builder group."""
    import sys

    argv = sys.argv
    sys.argv = ["aot", "--out", str(tmp_path), "--only", "quickstart"]
    try:
        aot.main()
    finally:
        sys.argv = argv
    with open(tmp_path / "manifest.json") as f:
        manifest = json.load(f)
    names = {a["name"] for a in manifest["artifacts"]}
    assert {"deer_gru_fwd", "gru_seq_fwd"} <= names
    entry = next(a for a in manifest["artifacts"] if a["name"] == "deer_gru_fwd")
    assert entry["inputs"][0]["name"] == "params"
    assert os.path.exists(tmp_path / entry["file"])
    # params binary is raw little-endian f32 of the declared length
    pbin = tmp_path / entry["params_file"]
    raw = pbin.read_bytes()
    assert len(raw) == 4 * entry["meta"]["param_len"]
    first = struct.unpack("<f", raw[:4])[0]
    assert np.isfinite(first)


def test_hnn_dynamics_is_symplectic():
    """The HNN vector field conserves H along its own flow: ∇H · f = 0."""
    from compile import models

    key = jax.random.PRNGKey(0)
    p = models.hnn_init(key, hidden=8, depth=3)
    s = jax.random.normal(key, (8,)) * 0.5
    f = models.hnn_dynamics(p, 0.0, s)
    grad_h = jax.grad(lambda ss: models.hnn_hamiltonian(p, ss))(s)
    assert abs(float(jnp.dot(grad_h, f))) < 1e-5
