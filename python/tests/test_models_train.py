"""L2 models + train steps: shapes, loss decrease, DEER-vs-sequential parity
inside full models (the §4.3/§4.4 claim that training curves coincide)."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import models as M
from compile import train as T


def _synthetic_worms(key, b, t, in_dim=6, classes=5):
    """Tiny stand-in for the synthetic EigenWorms generator (the real one is
    the Rust `data::worms`; this keeps parity tests cheap)."""
    kx, kl = jax.random.split(key)
    labels = jax.random.randint(kl, (b,), 0, classes)
    base = jax.random.normal(kx, (b, t, in_dim)) * 0.1
    tgrid = jnp.linspace(0, 8 * jnp.pi, t)
    freq = 0.5 + labels[:, None].astype(jnp.float32) * 0.35
    sig = jnp.sin(freq * tgrid[None, :])[:, :, None]
    return base + sig, labels


def test_worms_forward_shapes():
    key = jax.random.PRNGKey(0)
    p = M.worms_init(key, hidden=8, layers=2)
    xs = jax.random.normal(key, (40, 6))
    logits = M.worms_forward(p, xs, hidden=8)
    assert logits.shape == (5,)


def test_worms_deer_equals_sequential_forward():
    key = jax.random.PRNGKey(1)
    p = M.worms_init(key, hidden=8, layers=2)
    xs = jax.random.normal(key, (64, 6))
    a = M.worms_forward(p, xs, hidden=8, use_deer=True)
    b = M.worms_forward(p, xs, hidden=8, use_deer=False)
    np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-4)


def test_worms_training_reduces_loss():
    key = jax.random.PRNGKey(2)
    flat, _, step_fn, eval_fn = T.make_worms_fns(key, hidden=8, layers=1, use_deer=True, lr=3e-3)
    xs, labels = _synthetic_worms(jax.random.fold_in(key, 7), 8, 48)
    m = jnp.zeros_like(flat)
    v = jnp.zeros_like(flat)
    step = jnp.int32(0)
    step_fn = jax.jit(step_fn)
    loss0 = float(eval_fn(flat, xs, labels)[0])
    for _ in range(30):
        flat, m, v, step, loss, acc = step_fn(flat, m, v, step, xs, labels)
    loss1 = float(eval_fn(flat, xs, labels)[0])
    assert loss1 < loss0, f"{loss0} -> {loss1}"


def test_worms_deer_and_seq_training_match():
    """§4.3: DEER and sequential training produce the same trajectory (up to
    f32 noise) — check a few steps give nearly identical losses."""
    key = jax.random.PRNGKey(3)
    flat_d, _, step_d, _ = T.make_worms_fns(key, hidden=8, layers=1, use_deer=True, lr=1e-3)
    flat_s, _, step_s, _ = T.make_worms_fns(key, hidden=8, layers=1, use_deer=False, lr=1e-3)
    np.testing.assert_array_equal(flat_d, flat_s)
    xs, labels = _synthetic_worms(jax.random.fold_in(key, 9), 4, 32)
    md, vd = jnp.zeros_like(flat_d), jnp.zeros_like(flat_d)
    ms, vs = jnp.zeros_like(flat_s), jnp.zeros_like(flat_s)
    sd = ss = jnp.int32(0)
    for _ in range(5):
        flat_d, md, vd, sd, loss_d, _ = step_d(flat_d, md, vd, sd, xs, labels)
        flat_s, ms, vs, ss, loss_s, _ = step_s(flat_s, ms, vs, ss, xs, labels)
        np.testing.assert_allclose(loss_d, loss_s, rtol=1e-3)
    np.testing.assert_allclose(flat_d, flat_s, rtol=5e-2, atol=5e-4)


def test_hnn_training_reduces_loss():
    key = jax.random.PRNGKey(4)
    flat, unravel, step_fn, eval_fn = T.make_hnn_fns(key, hidden=16, depth=3, solver="deer", lr=3e-3)
    ts = jnp.linspace(0.0, 1.0, 33)
    # reference trajectories from a *target* HNN
    target = M.hnn_init(jax.random.fold_in(key, 5), hidden=16, depth=3)
    y0s = jax.random.normal(key, (2, 8)) * 0.4
    trajs = jax.vmap(lambda y0: M.hnn_rollout_rk4(target, ts, y0))(y0s)
    m = jnp.zeros_like(flat)
    v = jnp.zeros_like(flat)
    step = jnp.int32(0)
    step_fn = jax.jit(step_fn)
    loss0 = float(eval_fn(flat, ts, trajs))
    for _ in range(15):
        flat, m, v, step, loss = step_fn(flat, m, v, step, ts, trajs)
    loss1 = float(eval_fn(flat, ts, trajs))
    assert loss1 < loss0, f"{loss0} -> {loss1}"


def test_mhgru_strides_preserve_shape():
    key = jax.random.PRNGKey(5)
    p = M.mhgru_init(key, channels=8, heads=2, blocks=1)
    xs = jax.random.normal(key, (20, 3))  # T not divisible by strides
    logits = M.mhgru_forward(p, xs)
    assert logits.shape == (10,)


def test_mhgru_deer_equals_sequential():
    key = jax.random.PRNGKey(6)
    p = M.mhgru_init(key, channels=8, heads=2, blocks=1)
    xs = jax.random.normal(key, (32, 3))
    a = M.mhgru_forward(p, xs, use_deer=True)
    b = M.mhgru_forward(p, xs, use_deer=False)
    np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-4)


def test_adam_matches_reference_formula():
    p = jnp.array([1.0, -2.0])
    g = jnp.array([0.5, 0.1])
    m = jnp.zeros(2)
    v = jnp.zeros(2)
    p2, m2, v2 = T.adam_update(p, g, m, v, jnp.int32(1), lr=0.1)
    # first step: mhat = g, vhat = g², update = lr·g/(|g|+eps) = lr·sign(g)
    np.testing.assert_allclose(p2, p - 0.1 * jnp.sign(g), rtol=1e-4)
    assert m2.shape == v2.shape == (2,)


def test_grad_clip():
    g = jnp.array([3.0, 4.0])  # norm 5
    clipped = T.clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(jnp.linalg.norm(clipped), 1.0, rtol=1e-5)
    small = jnp.array([0.1, 0.1])
    np.testing.assert_allclose(T.clip_by_global_norm(small, 1.0), small)
