"""L1 kernel correctness: fused Pallas GRU cell+Jacobian vs oracle and AD."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.gru_cell import pallas_gru_f_jac, vmem_bytes


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(min_value=1, max_value=8),
    m=st.integers(min_value=1, max_value=6),
    t_pow=st.integers(min_value=3, max_value=7),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_fused_kernel_matches_reference(n, m, t_pow, seed):
    t = 2**t_pow
    key = jax.random.PRNGKey(seed)
    params = ref.gru_init(key, n, m)
    h = jax.random.normal(jax.random.fold_in(key, 1), (t, n)) * 0.7
    x = jax.random.normal(jax.random.fold_in(key, 2), (t, m))
    f_k, j_k = pallas_gru_f_jac(params, h, x, n=n, m=m, block=min(32, t))
    f_r, j_r = jax.vmap(lambda hh, xx: ref.gru_f_and_jac(params, hh, xx, n=n, m=m))(h, x)
    np.testing.assert_allclose(f_k, f_r, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(j_k, j_r, rtol=1e-5, atol=1e-5)


def test_analytic_jacobian_matches_autodiff():
    key = jax.random.PRNGKey(11)
    n, m = 6, 4
    params = ref.gru_init(key, n, m)
    h = jax.random.normal(jax.random.fold_in(key, 1), (n,)) * 0.5
    x = jax.random.normal(jax.random.fold_in(key, 2), (m,))
    _, j_analytic = ref.gru_f_and_jac(params, h, x, n=n, m=m)
    j_ad = jax.jacfwd(lambda hh: ref.gru_step(params, hh, x, n=n, m=m))(h)
    np.testing.assert_allclose(j_analytic, j_ad, rtol=1e-5, atol=1e-6)


def test_gru_step_matches_f_and_jac_f():
    key = jax.random.PRNGKey(12)
    n, m = 5, 3
    params = ref.gru_init(key, n, m)
    h = jax.random.normal(key, (n,)) * 0.3
    x = jax.random.normal(jax.random.fold_in(key, 1), (m,))
    f, _ = ref.gru_f_and_jac(params, h, x, n=n, m=m)
    f2 = ref.gru_step(params, h, x, n=n, m=m)
    np.testing.assert_allclose(f, f2, rtol=1e-6, atol=1e-7)


def test_vmem_budget():
    for n in [1, 8, 64]:
        assert vmem_bytes(256, n, n) < 16 * 2**20
