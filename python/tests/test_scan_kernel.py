"""L1 kernel correctness: Pallas affine scan vs pure-jnp oracle.

Hypothesis sweeps shapes and dtypes, as the paper's eq. (10)/(11) machinery
must hold for every (T, n) the DEER iteration feeds it.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.assoc_scan import pallas_affine_scan, vmem_bytes


def _random_affine(key, t, n, dtype, scale=0.5):
    k1, k2, k3 = jax.random.split(key, 3)
    a = jax.random.normal(k1, (t, n, n), dtype) * scale
    b = jax.random.normal(k2, (t, n), dtype)
    y0 = jax.random.normal(k3, (n,), dtype)
    return a, b, y0


@settings(max_examples=20, deadline=None)
@given(
    t_pow=st.integers(min_value=2, max_value=8),
    n=st.integers(min_value=1, max_value=6),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_pallas_scan_matches_sequential(t_pow, n, seed):
    t = 2**t_pow
    a, b, y0 = _random_affine(jax.random.PRNGKey(seed), t, n, jnp.float32)
    want = ref.seq_affine_scan(a, b, y0)
    got = pallas_affine_scan(a, b, y0, block=min(64, t))
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@settings(max_examples=10, deadline=None)
@given(
    n=st.integers(min_value=1, max_value=5),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_assoc_scan_matches_sequential(n, seed):
    t = 128
    a, b, y0 = _random_affine(jax.random.PRNGKey(seed), t, n, jnp.float32)
    want = ref.seq_affine_scan(a, b, y0)
    got = ref.assoc_affine_scan(a, b, y0)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_pallas_scan_f64():
    jax.config.update("jax_enable_x64", True)
    try:
        a, b, y0 = _random_affine(jax.random.PRNGKey(0), 64, 3, jnp.float64)
        want = ref.seq_affine_scan(a, b, y0)
        got = pallas_affine_scan(a, b, y0, block=16)
        np.testing.assert_allclose(got, want, rtol=1e-12, atol=1e-12)
    finally:
        jax.config.update("jax_enable_x64", False)


def test_reverse_scan_matches_loop():
    key = jax.random.PRNGKey(3)
    t, n = 37, 4
    a = jax.random.normal(key, (t, n, n)) * 0.4
    g = jax.random.normal(jax.random.fold_in(key, 1), (t, n))
    got_seq = ref.seq_reverse_scan(a, g)
    got_assoc = ref.assoc_reverse_scan(a, g)
    # naive python loop
    lam = np.zeros((t, n), np.float32)
    lam[t - 1] = np.asarray(g[t - 1])
    a_np, g_np = np.asarray(a), np.asarray(g)
    for i in range(t - 2, -1, -1):
        lam[i] = g_np[i] + a_np[i + 1].T @ lam[i + 1]
    np.testing.assert_allclose(got_seq, lam, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(got_assoc, lam, rtol=1e-4, atol=1e-4)


def test_combine_associativity():
    key = jax.random.PRNGKey(7)
    n = 3
    es = []
    for i in range(3):
        k = jax.random.fold_in(key, i)
        es.append(
            (
                jax.random.normal(k, (n, n)),
                jax.random.normal(jax.random.fold_in(k, 99), (n,)),
            )
        )
    left = ref.combine(es[2], ref.combine(es[1], es[0]))
    right = ref.combine(ref.combine(es[2], es[1]), es[0])
    np.testing.assert_allclose(left[0], right[0], rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(left[1], right[1], rtol=1e-5, atol=1e-5)


def test_block_must_divide():
    a, b, y0 = _random_affine(jax.random.PRNGKey(0), 100, 2, jnp.float32)
    with pytest.raises(AssertionError):
        pallas_affine_scan(a, b, y0, block=64)


def test_vmem_estimate_within_budget():
    # The documented TPU tiling: default block must fit a 16 MiB VMEM budget
    # for every n in the paper's sweep.
    for n in [1, 2, 4, 8, 16, 32, 64]:
        assert vmem_bytes(128, n) < 16 * 2**20
