//! End-to-end EigenWorms-style training (paper §4.3 / Fig. 4c–d / Table 1).
//!
//! The REQUIRED end-to-end driver: trains the GRU classifier through the
//! PJRT `worms_train_step` artifact (forward DEER evaluation, eq.-7 backward
//! and Adam all fused in one HLO executable) on the synthetic EigenWorms
//! generator, logs the loss/accuracy curve, evaluates on the validation
//! split, and records everything under results/.
//!
//! Run: `cargo run --release --example worms_classify -- [steps] [seed]`

use deer::util::err::Result;
use deer::data::{worms, Dataset, Split};
use deer::metrics::Recorder;
use deer::runtime::{Runtime, Tensor};
use deer::train::Trainer;
use deer::util::rng::Rng;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let steps: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(300);
    let seed: u64 = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(0);

    let rt = Runtime::load(&Runtime::default_dir())?;
    let rec = Recorder::new(&Recorder::default_dir())?;
    let spec = rt.manifest.get("worms_train_step").expect("run `make artifacts`").clone();
    let b = spec.meta["batch"] as usize;
    let t_len = spec.meta["t"] as usize;
    let eval_b = rt.manifest.get("worms_eval").unwrap().meta["batch"] as usize;
    println!("worms_train_step: batch={b} T={t_len} params={}", spec.meta["param_len"]);
    println!("(paper-scale T=17,984 runs through the pure-Rust engine in `deer bench --exp fig8`;");
    println!(" the artifact is compiled at T={t_len} for the 1-core CPU budget — see DESIGN.md §4)\n");

    // Synthetic EigenWorms at the artifact's sequence length; 70/15/15 split.
    let rows = 120;
    let (xs, labels) = worms::generate(rows, t_len, 1234 + seed);
    let ds = Dataset::new(xs, labels, t_len, worms::CHANNELS);

    let mut trainer = Trainer::new(&rt, "worms_train_step", "worms_train_step")?;
    let mut rng = Rng::new(seed);
    let t0 = std::time::Instant::now();
    for i in 0..steps {
        let (bx, bl, _) = ds.sample_batch(Split::Train, b, &mut rng);
        let data = [
            Tensor::f32(vec![b, t_len, worms::CHANNELS], bx),
            Tensor::i32(vec![b], bl),
        ];
        let (loss, acc) = trainer.step(&data)?;
        if i % 20 == 0 || i + 1 == steps {
            // validation
            let (val_loss, val_acc) = eval_split(&rt, &trainer, &ds, Split::Val, eval_b)?;
            println!(
                "step {:4}  [{:7.1?}]  train loss {loss:.4} acc {:.2}  |  val loss {val_loss:.4} acc {val_acc:.2}",
                i + 1,
                t0.elapsed(),
                acc.unwrap_or(0.0),
            );
            rec.log_line(
                "worms_classify",
                &format!("{} {:.3} {loss:.5} {val_loss:.5} {val_acc:.4}", i + 1, t0.elapsed().as_secs_f64()),
            )?;
        }
    }
    rec.curve("worms_classify_curve", &trainer.curve)?;

    let (test_loss, test_acc) = eval_split(&rt, &trainer, &ds, Split::Test, eval_b)?;
    println!("\nfinal test: loss {test_loss:.4}  acc {test_acc:.2}");
    println!("curve written to results/worms_classify_curve.csv");
    Ok(())
}

fn eval_split(
    rt: &Runtime,
    trainer: &Trainer,
    ds: &Dataset,
    split: Split,
    eval_b: usize,
) -> Result<(f64, f64)> {
    let mut losses = Vec::new();
    let mut accs = Vec::new();
    for idx in ds.batches(split, eval_b) {
        let (bx, bl) = ds.gather(&idx);
        let data = [
            Tensor::f32(vec![eval_b, ds.t, ds.channels], bx),
            Tensor::i32(vec![eval_b], bl),
        ];
        let (loss, acc) = trainer.eval("worms_eval", &data)?;
        losses.push(loss);
        accs.push(acc.unwrap_or(0.0));
    }
    let _ = rt;
    let n = losses.len().max(1) as f64;
    Ok((losses.iter().sum::<f64>() / n, accs.iter().sum::<f64>() / n))
}
