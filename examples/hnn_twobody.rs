//! HNN / NeuralODE training on two-body gravity (paper §4.2 / Fig. 4a–b).
//!
//! Two execution paths over identical physics:
//!
//! * **Artifact path** (when PJRT artifacts exist, `make artifacts`):
//!   trains the Hamiltonian Neural Network through the compiled
//!   `hnn_train_step_deer` / `hnn_train_step_rk4` programs and reports
//!   loss-vs-step and loss-vs-wall-clock (the Fig. 4(a)/(b) comparison).
//! * **Native path** (no artifacts needed): the same A/B entirely in-crate —
//!   ONE continuous-time `OdeCell<HamiltonianField>` trained twice from the
//!   same init, once integrating sequentially with RK4 + BPTT and once
//!   solving the same discretization grid with fused `deer_ode_batch` /
//!   `deer_ode_backward_batch`. A pure engine A/B on one model.
//!
//! Run: `cargo run --release --example hnn_twobody -- [steps]`

use deer::data::twobody;
use deer::metrics::Recorder;
use deer::runtime::{Runtime, Tensor};
use deer::train::Trainer;
use deer::util::err::Result;

fn main() -> Result<()> {
    let steps: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(60);
    match Runtime::load(&Runtime::default_dir()) {
        Ok(rt) if rt.manifest.get("hnn_train_step_deer").is_some() => artifact_run(&rt, steps),
        _ => {
            println!("PJRT artifacts not found — running the native continuous-time path\n");
            native_run(steps)
        }
    }
}

/// The in-crate A/B: sequential RK4 + BPTT vs fused DEER-ODE on one
/// `OdeCell<HamiltonianField>` (energy regression over two-body rollouts).
fn native_run(steps: usize) -> Result<()> {
    use deer::cells::{HamiltonianField, OdeCell};
    use deer::data::Split;
    use deer::deer::Interp;
    use deer::train::native::{
        twobody_task, ForwardMode, Model, Readout, TrainConfig, TrainLoop,
    };
    use deer::util::rng::Rng;

    let (rows, t_len, batch) = (40usize, 256usize, 8usize);
    let threads = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(2);
    let mut summary = Vec::new();
    for (label, mode) in [("DEER-ODE", ForwardMode::Deer), ("seq-RK4", ForwardMode::Seq)] {
        // identical data and init per arm: same seeds feed both runs
        let mut rng = Rng::new(0xD0E);
        let data = twobody_task(rows, t_len, 77);
        let cell = OdeCell::new(
            HamiltonianField::<f32>::new(twobody::STATE / 2, 32, &mut rng),
            0.02,
            1,
            Interp::Midpoint,
        );
        let model = Model::stacked(vec![cell], 1, Readout::MeanPool, &mut rng)?;
        let cfg = TrainConfig {
            mode,
            batch,
            lr: 3e-3,
            threads: if mode == ForwardMode::Seq { 1 } else { threads },
            ..Default::default()
        };
        let mut tl = TrainLoop::new(model, data, cfg)?;
        let t0 = std::time::Instant::now();
        let mut last = f64::NAN;
        for i in 0..steps {
            let s = tl.step();
            last = s.loss;
            if i % 10 == 0 || i + 1 == steps {
                println!(
                    "{label:8} step {:4} [{:7.1?}] train {:.6}",
                    s.step,
                    t0.elapsed(),
                    s.loss
                );
            }
        }
        let total = t0.elapsed().as_secs_f64();
        let (val_loss, _) = tl.eval(Split::Val);
        println!(
            "{label}: {steps} steps in {total:.1} s ({:.3} s/step), final train {last:.6}, val {val_loss:.6}\n",
            total / steps.max(1) as f64
        );
        summary.push((label, last, total));
    }
    let (deer, rk4) = (&summary[0], &summary[1]);
    println!("final train loss: {} {:.6} vs {} {:.6}", deer.0, deer.1, rk4.0, rk4.1);
    println!(
        "wall-clock per step: DEER-ODE {:.3} s vs seq-RK4 {:.3} s (ratio {:.2}x)",
        deer.2 / steps.max(1) as f64,
        rk4.2 / steps.max(1) as f64,
        rk4.2 / deer.2.max(1e-12)
    );
    Ok(())
}

fn artifact_run(rt: &Runtime, steps: usize) -> Result<()> {
    let rec = Recorder::new(&Recorder::default_dir())?;
    let spec = rt.manifest.get("hnn_train_step_deer").expect("checked by caller").clone();
    let b = spec.meta["batch"] as usize;
    let l = spec.meta["grid"] as usize;
    println!("HNN: {} params, batch={b}, grid={l} time points", spec.meta["param_len"]);

    // Paper setup scaled to the artifact grid: t ∈ [0, 10], L samples
    // (paper uses 10k samples; DESIGN.md documents the scaling).
    let t_end = 10.0;
    let ts: Vec<f32> = (0..l).map(|i| (t_end * i as f64 / (l - 1) as f64) as f32).collect();
    let train_trajs = twobody::generate(b, t_end, l, 100);
    let val_trajs = twobody::generate(b, t_end, l, 200);

    let mut curves = Vec::new();
    for (label, artifact) in [("DEER", "hnn_train_step_deer"), ("RK4", "hnn_train_step_rk4")] {
        // identical init: both read hnn_train_step_deer's shipped params
        let mut tr = Trainer::new(rt, artifact, "hnn_train_step_deer")?;
        let t0 = std::time::Instant::now();
        for i in 0..steps {
            let data = [
                Tensor::f32(vec![l], ts.clone()),
                Tensor::f32(vec![b, l, 8], train_trajs.clone()),
            ];
            let (loss, _) = tr.step(&data)?;
            if i % 10 == 0 || i + 1 == steps {
                let val = tr.eval(
                    "hnn_eval",
                    &[
                        Tensor::f32(vec![l], ts.clone()),
                        Tensor::f32(vec![b, l, 8], val_trajs.clone()),
                    ],
                )?;
                println!(
                    "{label:5} step {:4} [{:7.1?}] train {loss:.6}  val {:.6}",
                    i + 1,
                    t0.elapsed(),
                    val.0
                );
            }
        }
        let total = t0.elapsed().as_secs_f64();
        println!("{label}: {steps} steps in {total:.1} s ({:.2} s/step)\n", total / steps as f64);
        rec.curve(&format!("hnn_{}", label.to_lowercase()), &tr.curve)?;
        curves.push((label, tr.curve.clone(), total));
    }

    // Fig. 4(a)/(b) summary: same-step losses and the wall-clock ratio.
    let (deer, rk4) = (&curves[0], &curves[1]);
    let final_deer = deer.1.last().map(|p| p.loss).unwrap_or(f64::NAN);
    let final_rk4 = rk4.1.last().map(|p| p.loss).unwrap_or(f64::NAN);
    println!("final train loss: DEER {final_deer:.6} vs RK4 {final_rk4:.6}");
    println!(
        "wall-clock per step: DEER {:.3} s vs RK4 {:.3} s (ratio {:.2}x)",
        deer.2 / steps as f64,
        rk4.2 / steps as f64,
        rk4.2 / deer.2
    );
    println!("(paper reports 11x on V100 at L=10k; the CPU ratio at L={l} is smaller —");
    println!(" the simulated-device projection in `deer bench --exp fig7` covers the GPU regime)");
    Ok(())
}
