//! HNN / NeuralODE training on two-body gravity (paper §4.2 / Fig. 4a–b).
//!
//! Trains the Hamiltonian Neural Network twice through PJRT artifacts —
//! once rolling the NeuralODE out with **DEER** (`hnn_train_step_deer`) and
//! once with the sequential **RK4** baseline (`hnn_train_step_rk4`) — on
//! identical data and initialization, then reports loss-vs-step and
//! loss-vs-wall-clock for both (the Fig. 4(a)/(b) comparison).
//!
//! Run: `cargo run --release --example hnn_twobody -- [steps]`

use deer::util::err::Result;
use deer::data::twobody;
use deer::metrics::Recorder;
use deer::runtime::{Runtime, Tensor};
use deer::train::Trainer;

fn main() -> Result<()> {
    let steps: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(60);
    let rt = Runtime::load(&Runtime::default_dir())?;
    let rec = Recorder::new(&Recorder::default_dir())?;
    let spec = rt.manifest.get("hnn_train_step_deer").expect("run `make artifacts`").clone();
    let b = spec.meta["batch"] as usize;
    let l = spec.meta["grid"] as usize;
    println!("HNN: {} params, batch={b}, grid={l} time points", spec.meta["param_len"]);

    // Paper setup scaled to the artifact grid: t ∈ [0, 10], L samples
    // (paper uses 10k samples; DESIGN.md documents the scaling).
    let t_end = 10.0;
    let ts: Vec<f32> = (0..l).map(|i| (t_end * i as f64 / (l - 1) as f64) as f32).collect();
    let train_trajs = twobody::generate(b, t_end, l, 100);
    let val_trajs = twobody::generate(b, t_end, l, 200);

    let mut curves = Vec::new();
    for (label, artifact) in [("DEER", "hnn_train_step_deer"), ("RK4", "hnn_train_step_rk4")] {
        // identical init: both read hnn_train_step_deer's shipped params
        let mut tr = Trainer::new(&rt, artifact, "hnn_train_step_deer")?;
        let t0 = std::time::Instant::now();
        for i in 0..steps {
            let data = [
                Tensor::f32(vec![l], ts.clone()),
                Tensor::f32(vec![b, l, 8], train_trajs.clone()),
            ];
            let (loss, _) = tr.step(&data)?;
            if i % 10 == 0 || i + 1 == steps {
                let val = tr.eval(
                    "hnn_eval",
                    &[
                        Tensor::f32(vec![l], ts.clone()),
                        Tensor::f32(vec![b, l, 8], val_trajs.clone()),
                    ],
                )?;
                println!(
                    "{label:5} step {:4} [{:7.1?}] train {loss:.6}  val {:.6}",
                    i + 1,
                    t0.elapsed(),
                    val.0
                );
            }
        }
        let total = t0.elapsed().as_secs_f64();
        println!("{label}: {steps} steps in {total:.1} s ({:.2} s/step)\n", total / steps as f64);
        rec.curve(&format!("hnn_{}", label.to_lowercase()), &tr.curve)?;
        curves.push((label, tr.curve.clone(), total));
    }

    // Fig. 4(a)/(b) summary: same-step losses and the wall-clock ratio.
    let (deer, rk4) = (&curves[0], &curves[1]);
    let final_deer = deer.1.last().map(|p| p.loss).unwrap_or(f64::NAN);
    let final_rk4 = rk4.1.last().map(|p| p.loss).unwrap_or(f64::NAN);
    println!("final train loss: DEER {final_deer:.6} vs RK4 {final_rk4:.6}");
    println!(
        "wall-clock per step: DEER {:.3} s vs RK4 {:.3} s (ratio {:.2}x)",
        deer.2 / steps as f64,
        rk4.2 / steps as f64,
        rk4.2 / deer.2
    );
    println!("(paper reports 11x on V100 at L=10k; the CPU ratio at L={l} is smaller —");
    println!(" the simulated-device projection in `deer bench --exp fig7` covers the GPU regime)");
    Ok(())
}
