//! DEER as a general parallel ODE solver (paper §3.3, App. A.5/A.6).
//!
//! Pure-Rust demo, no artifacts needed: solves the two-body problem and a
//! stiff-ish forced oscillator with (a) adaptive RK45, (b) DEER fixed-point
//! iteration under each interpolation rule, comparing accuracy, Newton
//! iteration counts and the warm-start effect — then fuses a batch of
//! initial conditions into ONE `deer_ode_batch` call and checks each row is
//! bitwise identical to its standalone solve (per-row arithmetic is
//! independent; convergence is masked per sequence).
//!
//! Run: `cargo run --release --example ode_solver`

use deer::data::twobody::{self, TwoBody};
use deer::deer::newton::DeerConfig;
use deer::deer::ode::{deer_ode, deer_ode_batch, Interp, OdeSystem};
use deer::deer::rk45::{rk45_solve, Rk45Options};
use deer::util::rng::Rng;
use deer::util::table::Table;

struct ForcedOsc;
impl OdeSystem<f64> for ForcedOsc {
    fn dim(&self) -> usize {
        2
    }
    fn f(&self, t: f64, y: &[f64], out: &mut [f64]) {
        out[0] = y[1];
        out[1] = -4.0 * y[0] - 0.3 * y[1] + (2.0 * t).sin();
    }
    fn jac(&self, _t: f64, _y: &[f64], out: &mut [f64]) {
        out.copy_from_slice(&[0.0, 1.0, -4.0, -0.3]);
    }
}

fn main() {
    // --- two-body ---
    let mut rng = Rng::new(12);
    let ic = twobody::sample_ic(&mut rng);
    let l = 600;
    let t_end = 3.0;
    let ts: Vec<f64> = (0..l).map(|i| t_end * i as f64 / (l - 1) as f64).collect();

    let (rk, rk_steps, rk_fevals) =
        rk45_solve(&TwoBody, &ts, &ic, &Rk45Options::default()).expect("rk45");

    let mut table = Table::new(&["solver", "max err vs RK45", "iterations", "sequential depth"]);
    table.row(vec![
        "RK45 (baseline)".into(),
        "-".into(),
        format!("{rk_steps} steps"),
        format!("{rk_fevals} f-evals"),
    ]);
    for (name, interp) in [
        ("DEER midpoint", Interp::Midpoint),
        ("DEER left", Interp::Left),
        ("DEER right", Interp::Right),
    ] {
        let res = deer_ode(&TwoBody, &ts, &ic, None, interp, &DeerConfig { tol: 1e-9, ..Default::default() });
        let err = rk
            .iter()
            .zip(res.ys.iter())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f64, f64::max);
        table.row(vec![
            name.into(),
            format!("{err:.2e}"),
            format!("{} Newton iters", res.iterations),
            format!("log2(L) scan stages ≈ {}", (l as f64).log2().ceil()),
        ]);
    }
    println!("== Two-body gravitational system (L={l}, t∈[0,{t_end}]) ==\n{}", table.to_markdown());

    // energy drift check
    let e0 = twobody::energy(&ic.to_vec());
    let res = deer_ode(&TwoBody, &ts, &ic, None, Interp::Midpoint, &DeerConfig { tol: 1e-9, ..Default::default() });
    let e_end = twobody::energy(&res.ys[(l - 1) * 8..]);
    println!("energy drift over the horizon: {:.2e} (relative)\n", ((e_end - e0) / e0).abs());

    // --- fused batch: B initial conditions, ONE deer_ode_batch call ---
    let bsz = 4;
    let mut ics = Vec::with_capacity(bsz);
    let mut y0s = vec![0.0f64; bsz * 8];
    for b in 0..bsz {
        let ic = twobody::sample_ic(&mut rng);
        y0s[b * 8..(b + 1) * 8].copy_from_slice(&ic);
        ics.push(ic);
    }
    let cfg = DeerConfig { tol: 1e-9, ..Default::default() };
    let fused = deer_ode_batch(&TwoBody, &ts, &y0s, None, Interp::Midpoint, &cfg, bsz);
    println!("== Fused batch (B={bsz} two-body ICs, one deer_ode_batch call) ==");
    for b in 0..bsz {
        let single = deer_ode(&TwoBody, &ts, &ics[b], None, Interp::Midpoint, &cfg);
        assert_eq!(
            &fused.ys[b * l * 8..(b + 1) * l * 8],
            &single.ys[..],
            "row {b} must be bitwise identical to its standalone solve"
        );
        println!(
            "row {b}: {} Newton iterations, converged={} — bitwise equal to its B=1 solve",
            fused.iterations[b], fused.converged[b]
        );
    }
    println!();

    // --- forced oscillator: warm start ---
    let l2 = 2_000;
    let ts2: Vec<f64> = (0..l2).map(|i| 10.0 * i as f64 / (l2 - 1) as f64).collect();
    let y0 = [1.0, 0.0];
    let cold = deer_ode(&ForcedOsc, &ts2, &y0, None, Interp::Midpoint, &DeerConfig::default());
    let warm = deer_ode(
        &ForcedOsc,
        &ts2,
        &y0,
        Some(&cold.ys),
        Interp::Midpoint,
        &DeerConfig::default(),
    );
    println!("== Warm start (App. B.2) on the forced oscillator (L={l2}) ==");
    println!("cold start: {} iterations, converged={}", cold.iterations, cold.converged);
    println!("warm start: {} iterations (previous trajectory as initial guess)", warm.iterations);
    assert!(warm.iterations < cold.iterations);
    println!("\node_solver OK");
}
