//! Quickstart: the full three-layer stack in one page.
//!
//! 1. Loads the AOT artifacts (`make artifacts` first): `deer_gru_fwd` is the
//!    DEER evaluation of a GRU whose FUNCEVAL and INVLIN hot-spots are the
//!    Layer-1 **Pallas kernels**, lowered through the Layer-2 JAX graph into
//!    a single HLO module; `gru_seq_fwd` is the sequential baseline from the
//!    same parameters.
//! 2. Executes both through the Rust PJRT runtime and checks they agree
//!    (the paper's Fig. 3 claim).
//! 3. Repeats the same computation with the pure-Rust DEER engine and checks
//!    it against the artifacts — three independent implementations, one
//!    answer.
//!
//! Run: `cargo run --release --example quickstart`

use deer::util::err::Result;
use deer::cells::Gru;
use deer::deer::newton::{deer_rnn, DeerConfig};
use deer::deer::seq::seq_rnn;
use deer::runtime::{Runtime, Tensor};
use deer::util::rng::Rng;

fn main() -> Result<()> {
    let rt = Runtime::load(&Runtime::default_dir())?;
    let spec = rt.manifest.get("deer_gru_fwd").expect("run `make artifacts` first").clone();
    let n = spec.meta["n"] as usize;
    let m = spec.meta["m"] as usize;
    let t_len = spec.meta["t"] as usize;
    println!("artifact deer_gru_fwd: GRU n={n} m={m} T={t_len}");

    // Shared inputs: the artifact's shipped parameters + random sequence.
    let params = rt.load_params("deer_gru_fwd")?;
    let mut rng = Rng::new(0);
    let mut xs = vec![0.0f32; t_len * m];
    rng.fill_normal(&mut xs, 1.0);
    let h0 = vec![0.0f32; n];

    let inputs = [
        Tensor::f32(vec![params.len()], params.clone()),
        Tensor::f32(vec![n], h0.clone()),
        Tensor::f32(vec![t_len, m], xs.clone()),
    ];

    // (1) DEER via the Pallas-kernel artifact.
    let t0 = std::time::Instant::now();
    let ys_deer = rt.run("deer_gru_fwd", &inputs)?;
    let t_deer = t0.elapsed();
    let ys_deer = ys_deer[0].as_f32()?.to_vec();

    // (2) Sequential baseline artifact.
    let t0 = std::time::Instant::now();
    let ys_seq = rt.run("gru_seq_fwd", &inputs)?;
    let t_seq = t0.elapsed();
    let ys_seq = ys_seq[0].as_f32()?.to_vec();

    let max_err = ys_deer
        .iter()
        .zip(ys_seq.iter())
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    println!("PJRT   DEER(pallas) vs sequential: max |Δ| = {max_err:.3e}   (deer {t_deer:?}, seq {t_seq:?})");
    assert!(max_err < 2e-3, "artifact mismatch");

    // (3) The pure-Rust engine on the same parameters.
    let cell = Gru::<f32>::from_params(n, m, params);
    let rust_seq = seq_rnn(&cell, &h0, &xs);
    let rust_deer = deer_rnn(&cell, &h0, &xs, None, &DeerConfig::default());
    let err_rs = rust_deer
        .ys
        .iter()
        .zip(rust_seq.iter())
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    let err_cross = rust_seq
        .iter()
        .zip(ys_seq.iter())
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    println!(
        "Rust   DEER vs sequential: max |Δ| = {err_rs:.3e} ({} Newton iterations)",
        rust_deer.iterations
    );
    println!("Cross  Rust sequential vs PJRT sequential: max |Δ| = {err_cross:.3e}");
    assert!(err_rs < 2e-3);
    assert!(err_cross < 2e-3, "engines disagree: {err_cross}");

    println!("\nquickstart OK — three implementations, one trajectory.");
    Ok(())
}
